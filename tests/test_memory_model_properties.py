"""Property-based tests (hypothesis) on the analytical memory model's
invariants — the system's core correctness surface."""

import dataclasses

import pytest

pytest.importorskip(
    "hypothesis", reason="property suite needs hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_spec
from repro.core import (PAPER_CONFIG, ParallelConfig, RecomputePolicy,
                        ZeROStage, estimate_memory, stage_activation_bytes,
                        table4_stages, total_params_paper, zero_memory)
from repro.core.params import device_params, layer_params_paper

SPEC = get_spec("deepseek-v3")
SPECS = [get_spec(a) for a in
         ("deepseek-v3", "olmoe-1b-7b", "gemma-2b", "qwen2-1.5b",
          "qwen3-moe-235b-a22b", "rwkv6-1.6b", "hymba-1.5b")]


def cfg_strategy():
    return st.builds(
        lambda dp, tp, pp, ep, b, z, r, sp: ParallelConfig(
            dp=dp, tp=tp, pp=pp, ep=ep, etp=1, sp=sp, zero=z, recompute=r,
            micro_batch=b, seq_len=4096),
        dp=st.sampled_from([8, 16, 32, 64]),
        tp=st.sampled_from([1, 2, 4]),
        pp=st.sampled_from([1, 2, 4, 8, 16]),
        ep=st.sampled_from([1, 2, 4, 8]),
        b=st.sampled_from([1, 2, 4]),
        z=st.sampled_from(list(ZeROStage)),
        r=st.sampled_from(list(RecomputePolicy)),
        sp=st.booleans(),
    )


@settings(max_examples=60, deadline=None)
@given(cfg=cfg_strategy())
def test_pp_stages_partition_all_params(cfg):
    """Σ per-stage params == total params, for every PP degree."""
    for spec in SPECS:
        if cfg.pp > spec.n_layers:
            continue
        stages = table4_stages(spec, cfg.pp)
        assert sum(r.params for r in stages) == \
            sum(layer_params_paper(spec, i) for i in range(spec.n_layers))
        assert sum(len(r.layers) for r in stages) == spec.n_layers


@settings(max_examples=60, deadline=None)
@given(cfg=cfg_strategy())
def test_zero_monotonicity(cfg):
    """Each successive ZeRO stage uses <= memory (params+grads+opt)."""
    order = [ZeROStage.NONE, ZeROStage.OS, ZeROStage.OS_G,
             ZeROStage.OS_G_PARAMS]
    for spec in SPECS:
        if cfg.pp > spec.n_layers:
            continue
        if spec.is_moe and spec.moe.n_routed % cfg.ep:
            continue
        totals = [zero_memory(spec, dataclasses.replace(cfg, zero=z)).total
                  for z in order]
        assert totals == sorted(totals, reverse=True), (spec.name, totals)


@settings(max_examples=60, deadline=None)
@given(cfg=cfg_strategy())
def test_recompute_reduces_activation_memory(cfg):
    """FULL <= SELECTIVE <= NONE activation bytes."""
    for spec in SPECS:
        if cfg.pp > spec.n_layers:
            continue
        if spec.is_moe and spec.moe.n_routed % cfg.ep:
            continue
        vals = {}
        for r in RecomputePolicy:
            c = dataclasses.replace(cfg, recompute=r)
            vals[r] = stage_activation_bytes(spec, c)
        assert vals[RecomputePolicy.FULL] <= vals[RecomputePolicy.SELECTIVE] \
            <= vals[RecomputePolicy.NONE], (spec.name, vals)


@settings(max_examples=40, deadline=None)
@given(cfg=cfg_strategy(), scale=st.sampled_from([2, 4]))
def test_activation_memory_linear_in_batch(cfg, scale):
    """Doubling micro-batch scales activation bytes exactly linearly
    (all terms are linear in b)."""
    for spec in SPECS:
        if cfg.pp > spec.n_layers:
            continue
        if spec.is_moe and spec.moe.n_routed % cfg.ep:
            continue
        a1 = stage_activation_bytes(spec, cfg)
        c2 = dataclasses.replace(cfg, micro_batch=cfg.micro_batch * scale)
        a2 = stage_activation_bytes(spec, c2)
        assert a2 == scale * a1, (spec.name, a1, a2)


@settings(max_examples=40, deadline=None)
@given(cfg=cfg_strategy())
def test_tp_divides_tp_partitioned_params(cfg):
    """Doubling TP halves the TP-split attention share exactly
    (MLA geometry is 128-head divisible)."""
    c1 = dataclasses.replace(cfg, tp=1)
    c2 = dataclasses.replace(cfg, tp=2)
    d1 = device_params(SPEC, c1)
    d2 = device_params(SPEC, c2)
    assert d1.attn_tp == 2 * d2.attn_tp
    assert d1.attn_replicated == d2.attn_replicated   # replicated unaffected


@settings(max_examples=30, deadline=None)
@given(cfg=cfg_strategy())
def test_estimate_total_is_sum_of_parts(cfg):
    for spec in SPECS[:3]:
        if cfg.pp > spec.n_layers:
            continue
        if spec.is_moe and spec.moe.n_routed % cfg.ep:
            continue
        e = estimate_memory(spec, cfg)
        assert e.total == (e.params + e.grads + e.optimizer + e.activations
                           + e.comm_buffers + e.fragmentation)
        assert e.fragmentation == int(
            (e.params + e.grads + e.optimizer + e.activations
             + e.comm_buffers) * cfg.fragmentation)


@settings(max_examples=30, deadline=None)
@given(ep=st.sampled_from([1, 2, 4, 8, 16]))
def test_expert_params_scale_inverse_with_ep(ep):
    """Routed experts divide by EP; shared expert replicates (paper §3.3)."""
    cfg = dataclasses.replace(PAPER_CONFIG, ep=ep)
    d = device_params(SPEC, cfg)
    n_local = SPEC.moe.n_routed // ep
    per_expert = 3 * SPEC.h * SPEC.moe.d_ff_expert
    assert d.experts == 4 * (n_local + 1) * per_expert
