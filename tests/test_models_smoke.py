"""Per-architecture smoke tests: reduced variant (<=2 layers, d_model<=512,
<=4 experts) runs one forward + one train step on CPU; asserts output shapes
and absence of NaNs.  Also exercises one decode step per family."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_spec
from repro.core.notation import FamilyKind
from repro.data.synthetic import config_for, make_batch
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.optim.adamw import init_train_state
from repro.train.loop import TrainConfig, make_train_step

B, S = 2, 32


def _batch(spec):
    return make_batch(config_for(spec, B, S), step=0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    spec = get_spec(arch, smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(spec)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, spec.vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: NaN/Inf logits"
    assert jnp.isfinite(aux).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    spec = get_spec(arch, smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))
    state, metrics = step(state, _batch(spec))
    assert int(state.step) == 1
    assert jnp.isfinite(metrics["loss"]), f"{arch}: non-finite loss"
    assert jnp.isfinite(metrics["grad_norm"])
    assert float(metrics["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    spec = get_spec(arch, smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    enc_out = None
    if spec.encoder is not None:
        batch = _batch(spec)
        enc_out = model._encode(params, batch["audio_embeds"])
    cache = model.init_cache(B, cache_len=16, enc_out=enc_out)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, 1, spec.vocab)
        assert jnp.isfinite(logits.astype(jnp.float32)).all()
        tok = logits.argmax(-1).astype(jnp.int32)
    assert int(cache["index"]) == 3


def test_loss_decreases_dense():
    spec = get_spec("qwen2-1.5b", smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    batch = _batch(spec)
    losses = []
    for _ in range(20):
        state, m = step(state, batch)   # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_sliding_window_decode_matches_full_within_window():
    """Ring-buffer decode == full-cache decode while index < window."""
    import dataclasses
    spec = get_spec("qwen2-1.5b", smoke=True)
    model_full = build_model(spec)
    spec_w = dataclasses.replace(spec, sliding_window=16)
    model_win = build_model(spec_w)
    params = model_full.init(jax.random.PRNGKey(1))
    c_full = model_full.init_cache(B, cache_len=16)
    c_win = model_win.init_cache(B, cache_len=16)
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(4):
        lf, c_full = jax.jit(model_full.decode_step)(params, c_full, tok)
        lw, c_win = jax.jit(model_win.decode_step)(params, c_win, tok)
        assert jnp.allclose(lf, lw, atol=2e-2), "window decode diverged early"
        tok = lf.argmax(-1).astype(jnp.int32)
