"""Docs health (the CI docs job): every intra-repo markdown link in
README.md / docs/*.md resolves to a real file, and every ``repro.*``
import or ``python -m repro...`` module referenced by a docs code snippet
actually imports — so the docs cannot drift from the package silently."""

import ast
import glob
import importlib
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = sorted([os.path.join(ROOT, "README.md")]
                   + glob.glob(os.path.join(ROOT, "docs", "*.md")))

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)
_MODULE_RE = re.compile(r"-m\s+(repro(?:\.\w+)+)")


def _md(path):
    with open(path) as f:
        return f.read()


@pytest.mark.parametrize("path", DOC_FILES,
                         ids=[os.path.relpath(p, ROOT) for p in DOC_FILES])
def test_intra_repo_links_resolve(path):
    text = _md(path)
    missing = []
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            missing.append(target)
    assert not missing, f"{os.path.relpath(path, ROOT)}: dead links {missing}"


def _repro_imports(code):
    """(module, [names]) pairs for every ``repro.*`` import in a snippet.
    Snippets may be illustrative fragments (``>>>`` transcripts, elided
    bodies), so non-parsing blocks are scanned line-by-line."""
    out = []
    try:
        tree = ast.parse(code)
    except SyntaxError:
        lines = [l[4:] if l.startswith(">>> ") else l
                 for l in code.splitlines()
                 if l.startswith(">>> ") or l.startswith(("import repro",
                                                          "from repro"))]
        joined = "\n".join(l for l in lines
                           if l.startswith(("import repro", "from repro")))
        try:
            tree = ast.parse(joined)
        except SyntaxError:
            return out
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.split(".")[0] == "repro":
            out.append((node.module, [a.name for a in node.names]))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "repro":
                    out.append((a.name, []))
    return out


@pytest.mark.parametrize("path", DOC_FILES,
                         ids=[os.path.relpath(p, ROOT) for p in DOC_FILES])
def test_snippet_symbols_import(path):
    bad = []
    for lang, code in _FENCE_RE.findall(_md(path)):
        if lang in ("python", "py", ""):
            for mod, names in _repro_imports(code):
                try:
                    m = importlib.import_module(mod)
                except ImportError as e:
                    bad.append(f"{mod}: {e}")
                    continue
                for n in names:
                    if n != "*" and not hasattr(m, n):
                        bad.append(f"{mod}.{n}")
        if lang in ("bash", "sh", "shell", ""):
            # find_spec, not import: repro.launch.dryrun sets XLA_FLAGS at
            # import time, which must not leak into this pytest process
            for mod in _MODULE_RE.findall(code):
                if importlib.util.find_spec(mod) is None:
                    bad.append(mod)
    assert not bad, \
        f"{os.path.relpath(path, ROOT)}: snippet symbols missing: {bad}"
