"""Exactness tests for paper §5 Table 10 activation formulas.

The paper gives closed forms for a 4-layer PP stage at TP2@SP2@CP1@EP8:
  MLA  AC-None : 10bsh + 8bs(d_cq+d_c) + 16bs d_h n_h + 8bs d_hr n_h + 10 b n_h s^2
  MLA  AC-Full : 4bsh
  MoE  AC-None : 20bsh + 16bsN + 8bsN_r + 4bs N_r/N (96h + 256h_E) + 32bs h_E
  MoE  AC-Full : 4bsh + 8bsN_r
We evaluate our symbolic model at the paper's settings and compare.
"""

import dataclasses

import pytest

from repro.configs import get_spec
from repro.core.activations import table10
from repro.core.parallel_config import PAPER_CONFIG

SPEC = get_spec("deepseek-v3")

H, HE = 7168, 2048
DCQ, DC = 1536, 512
DH, DHR, NH = 128, 64, 128
N, NR = 256, 8
S = 4096


def paper_mla_none(b, s=S):
    return (10 * b * s * H + 8 * b * s * (DCQ + DC) + 16 * b * s * DH * NH
            + 8 * b * s * DHR * NH + 10 * b * NH * s * s)


def paper_moe_none(b, s=S):
    return (20 * b * s * H + 16 * b * s * N + 8 * b * s * NR
            + 4 * b * s * NR // N * (96 * H + 256 * HE) + 32 * b * s * HE)


@pytest.mark.parametrize("b", [1, 2, 4])
def test_table10_ac_none(b):
    cfg = dataclasses.replace(PAPER_CONFIG, micro_batch=b)
    t = table10(SPEC, cfg)["none"]
    assert t["MLA"] == paper_mla_none(b)
    assert t["MoE"] == paper_moe_none(b)
    assert t["Total"] == paper_mla_none(b) + paper_moe_none(b)


@pytest.mark.parametrize("b", [1, 2, 4])
def test_table10_ac_full(b):
    cfg = dataclasses.replace(PAPER_CONFIG, micro_batch=b)
    t = table10(SPEC, cfg)["full"]
    assert t["MLA"] == 4 * b * S * H
    assert t["MoE"] == 4 * b * S * H + 8 * b * S * NR
    assert t["Total"] == 8 * b * S * H + 8 * b * S * NR


def test_scores_term_magnitude():
    # at b=1, s=4096 the 10 b n_h s^2 term is ~20 GiB — dominates; sanity-check
    assert 10 * 1 * NH * S * S == 21_474_836_480
