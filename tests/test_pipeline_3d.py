"""3D runtime equivalence: the pipeline executor on ('pipe','data','model')
meshes — TP inside every rank (manual Megatron collectives, vocab-parallel
CE) and ZeRO state sharding over the per-stage DP group — reproduces the
single-device step's loss and post-update master params to
bf16-accumulation tolerance.

Fast tier: one dense pp2×dp2×tp2 run with ZeRO-1 on.  Slow tier: the full
schedule × pp{2,4} × tp{2} × dp{1,2} grid, the MoE/MLA families, and the
ZeRO-1 state-sharding invariant (each DP shard holds 1/dp of the optimizer
bytes; the sharded AdamW update reassembles to the replicated one).

Needs >1 fake device set before jax initialises — subprocess with XLA_FLAGS.
"""

import os
import subprocess
import sys
import textwrap

import pytest

HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    def check(tag, m1, s1, m2, s2, tol_loss=5e-3, tol_p=2e-2):
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < tol_loss, f"{tag}: loss diverged {dl}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < tol_p, f"{tag}: master params diverged {worst}"
        print(f"{tag}_OK", dl, worst)
""")

DENSE_FAST = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                    zero=ZeROStage.OS)
    s2, m2 = jax.jit(step)(state, batch)
    check("PP2_DP2_TP2_ZOS", m1, s1, m2, s2)
""")

DENSE_GRID_BODY = textwrap.dedent("""
    SCHEDULE = {schedule!r}
    N_CHUNKS = {n_chunks}
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    for pp, data, tp in [(2, 2, 2), (2, 1, 2), (4, 1, 2)]:
        mesh = jax.make_mesh((pp, data, tp), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        schedule=SCHEDULE, n_chunks=N_CHUNKS,
                                        zero=ZeROStage.OS)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"PP{{pp}}_DP{{data}}_TP{{tp}}", m1, s1, m2, s2)
""")


def dense_grid_script(schedule, n_chunks):
    return HEADER + DENSE_GRID_BODY.format(schedule=schedule,
                                           n_chunks=n_chunks)

MOE_TP = HEADER + textwrap.dedent("""
    # olmoe: all-MoE softmax router (loss tol = routing noise, see
    # test_pipeline_1f1b); deepseek: MLA + mixed dense/MoE + sigmoid router
    # + shared expert — expert-ff (ETP) sharding and the MLA latent-tower
    # collectives end to end, with ZeRO-1 on.
    for name, layers, data, tol in [("olmoe-1b-7b", 4, 2, 1e-1),
                                    ("deepseek-v3", 4, 1, 5e-3)]:
        spec = dataclasses.replace(get_spec(name, smoke=True), n_layers=layers)
        model = build_model(spec)
        state = init_train_state(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(config_for(spec, 4, 32), 0)
        s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
        mesh = jax.make_mesh((2, data, 2), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=2), mesh,
                                        zero=ZeROStage.OS)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"{name}_TP2", m1, s1, m2, s2, tol_loss=tol)
""")

ZERO_INVARIANT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.models import build_model
    from repro.optim.adamw import (AdamWConfig, adamw_update,
                                   init_train_state)
    from repro.parallel.sharding import state_shardings

    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    dp = mesh.shape["data"]

    def dev0_bytes(tree):
        return sum(x.addressable_shards[0].data.nbytes
                   for x in jax.tree.leaves(tree))

    sh_none = state_shardings(state, mesh, ZeROStage.NONE)
    sh_os = state_shardings(state, mesh, ZeROStage.OS)
    st_none = jax.device_put(state, sh_none)
    st_os = jax.device_put(state, sh_os)
    for field in ("master", "m", "v"):
        full = dev0_bytes(getattr(st_none, field))
        shard = dev0_bytes(getattr(st_os, field))
        ratio = shard / full
        # every leaf of the smoke model admits a DP dim -> exactly 1/dp
        assert abs(ratio - 1.0 / dp) < 0.05, (field, ratio)
        print(f"{field}: per-device {ratio:.3f} of replicated (dp={dp})")
    # params stay un-DP-sharded below ZeRO-3
    assert dev0_bytes(st_os.params) == dev0_bytes(st_none.params)

    # the sharded AdamW update reassembles to the replicated one
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape,
                                    jnp.float32) * 1e-3, state.params)
    ref, _ = jax.jit(adamw_update, static_argnums=2)(state, grads,
                                                     AdamWConfig())
    out, _ = jax.jit(adamw_update, static_argnums=2,
                     out_shardings=((sh_os, None)))(st_os, grads,
                                                    AdamWConfig())
    for a, b in zip(jax.tree.leaves(ref.master), jax.tree.leaves(out.master)):
        assert jnp.allclose(a, jax.device_get(b), atol=1e-6), "update diverged"
    print("ZERO1_INVARIANT_OK")
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_pipeline_3d_dense_fast():
    """pp2 × dp2 × tp2 with ZeRO-1: the tier-1 3D smoke."""
    r = _run(DENSE_FAST)
    assert "PP2_DP2_TP2_ZOS_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
@pytest.mark.parametrize("schedule,n_chunks",
                         [("1f1b", 1), ("interleaved", 2), ("dualpipe", 2)])
def test_pipeline_3d_grid(schedule, n_chunks):
    """schedule × pp{2,4} × tp2 × dp{1,2} vs the single-device step."""
    r = _run(dense_grid_script(schedule, n_chunks))
    for tag in ("PP2_DP2_TP2_OK", "PP2_DP1_TP2_OK", "PP4_DP1_TP2_OK"):
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_pipeline_3d_moe():
    r = _run(MOE_TP)
    assert "olmoe-1b-7b_TP2_OK" in r.stdout \
        and "deepseek-v3_TP2_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_zero1_state_sharding_invariant():
    """Each DP shard holds 1/dp of the optimizer bytes; the sharded AdamW
    update matches the replicated one after reassembly."""
    r = _run(ZERO_INVARIANT)
    assert "ZERO1_INVARIANT_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
