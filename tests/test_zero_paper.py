"""Exactness tests for paper §4 Table 8 (ZeRO memory) with Table 7 dtypes."""

import dataclasses

import pytest

from repro.configs import get_spec
from repro.core.parallel_config import PAPER_CONFIG, ZeROStage
from repro.core.zero import zero_memory, zero_table

SPEC = get_spec("deepseek-v3")
GiB = 2**30


def test_zero_none():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.NONE))
    assert m.params == 12_500_729_856                      # 11.64 GiB
    assert m.grads == 6_250_364_928 * 4                    # 23.3 GiB
    assert m.optimizer == 6_250_364_928 * 8                # 46.6 GiB
    assert round(m.params / GiB, 2) == 11.64
    assert round(m.grads / GiB, 1) == 23.3
    assert round(m.optimizer / GiB, 1) == 46.6
    # paper's P+G+O column sums the rounded per-column GiB values
    assert round(m.params / GiB, 2) + round(m.grads / GiB, 1) \
        + round(m.optimizer / GiB, 1) == pytest.approx(81.54)
    assert round(m.total / GiB, 1) == 81.5                 # exact bytes


def test_zero_os():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.OS))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.optimizer == shard * 8 == 5_928_075_264       # 5.52 GiB
    assert round(m.optimizer / GiB, 2) == 5.52
    assert m.params == 12_500_729_856
    assert m.grads == 6_250_364_928 * 4
    assert round(m.params / GiB, 2) + round(m.grads / GiB, 1) \
        + round(m.optimizer / GiB, 2) == pytest.approx(40.46)  # paper's rounded sum
    assert round(m.total / GiB, 2) == 40.45                # exact bytes


def test_zero_os_g():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.OS_G))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.grads == shard * 4
    assert round(m.grads / GiB, 2) == 2.76
    assert round(m.total / GiB, 2) == 19.92


def test_zero_os_g_params():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG,
                                              zero=ZeROStage.OS_G_PARAMS))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.params == shard * 2
    assert round(m.params / GiB, 2) == 1.38
    assert round(m.total / GiB, 2) == 9.66


def test_sharded_ceil_rounding():
    """Regression: shard terms must ceil-divide, not floor-divide.  With a
    DP degree prime to the per-device parameter count, floor division
    undercounts — every rank's shard is ceil(n/group)-sized (the last rank
    pads), so shards x group must cover the total."""
    from repro.core.params import device_params

    spec = get_spec("qwen2-1.5b")
    cfg = dataclasses.replace(PAPER_CONFIG, dp=7, tp=1, ep=1, etp=1,
                              zero=ZeROStage.OS)
    dev = device_params(spec, cfg)
    assert dev.non_expert % 7, "pick a dp that does NOT divide the count"
    m = zero_memory(spec, cfg)
    shard_opt = m.optimizer // 8                 # per-rank sharded count
    assert shard_opt * 7 >= dev.total, (shard_opt, dev.total)
    assert shard_opt == -(-dev.total // 7)       # exactly the ceil quotient
    m3 = zero_memory(spec, dataclasses.replace(cfg,
                                               zero=ZeROStage.OS_G_PARAMS))
    assert (m3.params // 2) * 7 >= dev.total
    assert (m3.grads // 4) * 7 >= dev.total


def test_zero_table_monotone():
    tbl = zero_table(SPEC, PAPER_CONFIG)
    totals = [tbl[z.value].total for z in ZeROStage]
    assert totals == sorted(totals, reverse=True)
