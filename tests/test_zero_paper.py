"""Exactness tests for paper §4 Table 8 (ZeRO memory) with Table 7 dtypes."""

import dataclasses

import pytest

from repro.configs import get_spec
from repro.core.parallel_config import PAPER_CONFIG, ZeROStage
from repro.core.zero import zero_memory, zero_table

SPEC = get_spec("deepseek-v3")
GiB = 2**30


def test_zero_none():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.NONE))
    assert m.params == 12_500_729_856                      # 11.64 GiB
    assert m.grads == 6_250_364_928 * 4                    # 23.3 GiB
    assert m.optimizer == 6_250_364_928 * 8                # 46.6 GiB
    assert round(m.params / GiB, 2) == 11.64
    assert round(m.grads / GiB, 1) == 23.3
    assert round(m.optimizer / GiB, 1) == 46.6
    # paper's P+G+O column sums the rounded per-column GiB values
    assert round(m.params / GiB, 2) + round(m.grads / GiB, 1) \
        + round(m.optimizer / GiB, 1) == pytest.approx(81.54)
    assert round(m.total / GiB, 1) == 81.5                 # exact bytes


def test_zero_os():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.OS))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.optimizer == shard * 8 == 5_928_075_264       # 5.52 GiB
    assert round(m.optimizer / GiB, 2) == 5.52
    assert m.params == 12_500_729_856
    assert m.grads == 6_250_364_928 * 4
    assert round(m.params / GiB, 2) + round(m.grads / GiB, 1) \
        + round(m.optimizer / GiB, 2) == pytest.approx(40.46)  # paper's rounded sum
    assert round(m.total / GiB, 2) == 40.45                # exact bytes


def test_zero_os_g():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG, zero=ZeROStage.OS_G))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.grads == shard * 4
    assert round(m.grads / GiB, 2) == 2.76
    assert round(m.total / GiB, 2) == 19.92


def test_zero_os_g_params():
    m = zero_memory(SPEC, dataclasses.replace(PAPER_CONFIG,
                                              zero=ZeROStage.OS_G_PARAMS))
    shard = 429_719_552 // 32 + 5_820_645_376 // 8
    assert m.params == shard * 2
    assert round(m.params / GiB, 2) == 1.38
    assert round(m.total / GiB, 2) == 9.66


def test_zero_table_monotone():
    tbl = zero_table(SPEC, PAPER_CONFIG)
    totals = [tbl[z.value].total for z in ZeROStage]
    assert totals == sorted(totals, reverse=True)
