"""Sharding-rule unit tests: param specs follow the paper's §3 partitioning,
ZeRO stages add data-axis sharding, and a small-mesh pjit train step runs
end-to-end with sharded state."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_spec
from repro.core.parallel_config import ZeROStage
from repro.models import build_model
from repro.optim.adamw import init_train_state
from repro.parallel.sharding import (add_dp_axes, grad_shardings,
                                     param_specs, state_shardings)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mesh_1x1():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_param_specs_follow_paper_rules():
    spec = get_spec("deepseek-v3", smoke=True)
    model = build_model(spec)
    abstract = model.abstract_params()
    mesh = _mesh_1x1()
    specs = param_specs(abstract, mesh)
    moe = specs["moe_layers"]
    # experts sharded on the expert dim (EP), ETP=1 → no inner split (§3.3)
    assert moe["moe"]["we_gate"] == P(None, "model", None, None)
    assert moe["moe"]["we_down"] == P(None, "model", None, None)
    # router replicated (§3.3)
    assert moe["moe"]["router"] == P(None, None, None)
    # MLA: up/out projections TP-split; down-projections replicated (§3.2)
    assert moe["attn"]["w_uq"] == P(None, None, "model")
    assert moe["attn"]["w_o"] == P(None, "model", None)
    assert moe["attn"]["w_dq"] == P(None, None, None)
    assert moe["attn"]["w_dkv"] == P(None, None, None)
    assert moe["attn"]["w_kr"] == P(None, None, None)
    # norms replicated
    assert moe["ln1"]["scale"] == P(None, None)
    # embedding vocab-sharded
    assert specs["embed"]["w"] == P("model", None)


def test_add_dp_axes_picks_divisible_dim():
    mesh = _mesh_1x1()
    s = add_dp_axes(P(None, "model"), (7, 64), mesh)
    assert s == P(("data",), "model") or s == P("data", "model")
    # indivisible everywhere -> unchanged
    s2 = add_dp_axes(P(), (3,), Mesh(np.array(jax.devices()[:1]).reshape(1,),
                                     ("data",)))
    # 3 % 1 == 0 with a 1-sized axis; use a logical check instead:
    assert s2 in (P(("data",)), P("data"), P())


def test_zero_stage_monotone_sharding():
    """More aggressive ZeRO stages shard strictly more state pytrees."""
    spec = get_spec("qwen2-1.5b", smoke=True)
    model = build_model(spec)
    abstract_state = jax.eval_shape(init_train_state, model.abstract_params())
    mesh = _mesh_1x1()

    def count_dp(tree):
        n = 0
        for sh in jax.tree.leaves(tree,
                                  is_leaf=lambda x: isinstance(x, NamedSharding)):
            spec_ = sh.spec
            names = [a for e in spec_ if e for a in
                     ((e,) if isinstance(e, str) else e)]
            if "data" in names:
                n += 1
        return n

    none = state_shardings(abstract_state, mesh, ZeROStage.NONE)
    os_ = state_shardings(abstract_state, mesh, ZeROStage.OS)
    osgp = state_shardings(abstract_state, mesh, ZeROStage.OS_G_PARAMS)
    assert count_dp(none.master) == 0
    assert count_dp(os_.master) > 0
    assert count_dp(none.params) == 0
    assert count_dp(os_.params) == 0
    assert count_dp(osgp.params) > 0
    g_none = grad_shardings(model.abstract_params(), mesh, ZeROStage.OS)
    g_shard = grad_shardings(model.abstract_params(), mesh, ZeROStage.OS_G)
    assert count_dp(g_none) == 0
    assert count_dp(g_shard) > 0


def test_pjit_train_step_with_sharded_state():
    """End-to-end: jit with in/out shardings on a 1x1 mesh (degenerate but
    exercises the full sharding plumbing the dry-run uses)."""
    from repro.data.synthetic import config_for, make_batch
    from repro.launch.specs import batch_shardings
    from repro.parallel.axes import axis_rules
    from repro.train.loop import TrainConfig, make_train_step

    spec = get_spec("olmoe-1b-7b", smoke=True)
    model = build_model(spec)
    mesh = _mesh_1x1()
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    abstract_state = jax.eval_shape(lambda: state)
    st_sh = state_shardings(abstract_state, mesh, ZeROStage.OS_G)
    batch = make_batch(config_for(spec, 2, 16), 0)
    b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
    step = make_train_step(model, TrainConfig())
    with axis_rules(mesh):
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
        new_state, metrics = fn(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.step) == 1
