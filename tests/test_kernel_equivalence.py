"""Pallas-vs-reference equivalence for the kernel-backend dispatch.

Three tiers, matching the dispatch layers in ``repro.models.backend``:

* standalone ops — bf16 forward AND gradient agreement for rmsnorm /
  flash attention / grouped-mlp between ``backend="pallas"`` (interpret
  mode on CPU) and the jnp reference;
* the 3D executor — one pp2×dp2×tp2 pipeline step under
  ``ModelOptions(backend="pallas")`` reproduces the reference step's loss
  and first-moment norms (subprocess with XLA_FLAGS fake devices, same
  harness as test_zero3_equivalence);
* the memory model — ``attn_impl="flash"`` drops *exactly* the
  5·b·n_h·s² score/softmax/mask term at AC-None and nothing else
  (hypothesis property over b/s/tp/recompute).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import backend as B

# bf16 tolerances: the pallas forwards accumulate in fp32 but inputs and
# outputs are bf16 (~3 decimal digits); backwards go through the jnp
# oracle's vjp on both paths, so grads agree tighter than forwards.
ATOL_FWD, ATOL_GRAD = 5e-2, 5e-2


def _assert_close(tag, a, b, atol):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    np.testing.assert_allclose(a, b, atol=atol, rtol=atol, err_msg=tag)


# ---------------------------------------------------------------------------
# Standalone ops: forward + grads, bf16
# ---------------------------------------------------------------------------

def test_rmsnorm_equivalence_bf16():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 256), jnp.bfloat16)
    p = {"scale": jnp.ones((256,), jnp.bfloat16)
         + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (256,), jnp.bfloat16)}

    for gemma in (False, True):
        def f(params, inp, backend):
            y = B.rmsnorm(params, inp, 1e-6, gemma_style=gemma,
                          backend=backend)
            return jnp.sum(y.astype(jnp.float32) ** 2), y

        (l_r, y_r), g_r = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)(
            p, x, "reference")
        (l_p, y_p), g_p = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)(
            p, x, "pallas")
        _assert_close(f"rmsnorm fwd gemma={gemma}", y_p, y_r, ATOL_FWD)
        assert abs(float(l_p) - float(l_r)) < 1e-2 * max(abs(float(l_r)), 1.0)
        _assert_close("rmsnorm dscale", g_p[0]["scale"], g_r[0]["scale"],
                      ATOL_GRAD * 10)     # dscale sums 64 rows of bf16
        _assert_close("rmsnorm dx", g_p[1], g_r[1], ATOL_GRAD)


def test_flash_attention_equivalence_bf16():
    b, s, nh, d = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (b, s, nh, d), jnp.bfloat16) for kk in ks)
    scale = d ** -0.5

    def f(q_, k_, v_, impl):
        y = B.attention(q_, k_, v_, scale=scale, impl=impl)
        return jnp.sum(y.astype(jnp.float32) ** 2), y

    (_, y_r), g_r = jax.value_and_grad(f, argnums=(0, 1, 2), has_aux=True)(
        q, k, v, "naive")
    (_, y_p), g_p = jax.value_and_grad(f, argnums=(0, 1, 2), has_aux=True)(
        q, k, v, "pallas")
    _assert_close("attn fwd", y_p, y_r, ATOL_FWD)
    for name, gp, gr in zip("qkv", g_p, g_r):
        _assert_close(f"attn d{name}", gp, gr, ATOL_GRAD)


def test_mla_attention_equivalence_bf16_dq_neq_dv():
    # MLA shape: query/key dim (d_h + d_hr) != value dim d_v
    b, s, nh, dq, dv = 1, 128, 2, 96, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (b, s, nh, dq), jnp.bfloat16)
    k = jax.random.normal(kk, (b, s, nh, dq), jnp.bfloat16)
    v = jax.random.normal(kv, (b, s, nh, dv), jnp.bfloat16)
    scale = dq ** -0.5
    y_r = B.mla_attention(q, k, v, scale=scale, impl="naive")
    y_p = B.mla_attention(q, k, v, scale=scale, impl="pallas")
    assert y_p.shape == (b, s, nh, dv)
    _assert_close("mla fwd dq!=dv", y_p, y_r, ATOL_FWD)


def test_grouped_mlp_equivalence_bf16():
    E, C, h, f = 4, 64, 64, 128
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    buf = jax.random.normal(keys[0], (E, C, h), jnp.bfloat16)
    wg = 0.1 * jax.random.normal(keys[1], (E, h, f), jnp.bfloat16)
    wu = 0.1 * jax.random.normal(keys[2], (E, h, f), jnp.bfloat16)
    wd = 0.1 * jax.random.normal(keys[3], (E, f, h), jnp.bfloat16)

    def g(buf_, wg_, wu_, wd_, backend):
        y = B.grouped_mlp(buf_, wg_, wu_, wd_, backend=backend)
        return jnp.sum(y.astype(jnp.float32) ** 2), y

    (_, y_r), g_r = jax.value_and_grad(g, argnums=(0, 1, 2, 3), has_aux=True)(
        buf, wg, wu, wd, "reference")
    (_, y_p), g_p = jax.value_and_grad(g, argnums=(0, 1, 2, 3), has_aux=True)(
        buf, wg, wu, wd, "pallas")
    _assert_close("gmm fwd", y_p, y_r, ATOL_FWD)
    for name, gp, gr in zip(("dbuf", "dwg", "dwu", "dwd"), g_p, g_r):
        _assert_close(f"gmm {name}", gp, gr, ATOL_GRAD)


def test_unsupported_flash_request_warns_with_reason():
    """Satellite: the fallback is loud and names the reason — sliding
    window and non-causal both refuse the kernel."""
    b, s, nh, d = 1, 32, 2, 16
    q = k = v = jnp.ones((b, s, nh, d), jnp.bfloat16)
    with pytest.warns(RuntimeWarning, match="sliding_window"):
        B.attention(q, k, v, scale=0.25, impl="pallas", window=8)
    with pytest.warns(RuntimeWarning, match="causal=False"):
        B.attention(q, k, v, scale=0.25, impl="pallas", causal=False)
    # the supported case is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        B.attention(q, k, v, scale=0.25, impl="pallas")


# ---------------------------------------------------------------------------
# The 3D executor: backend="pallas" inside pp2 × dp2 × tp2
# ---------------------------------------------------------------------------

PALLAS_3D = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.models.transformer import ModelOptions
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig
    from repro.train.pipeline_loop import make_pipeline_train_step

    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    m_ref = build_model(spec, ModelOptions(backend="reference"))
    m_pal = build_model(spec, ModelOptions(backend="pallas"))
    params = m_ref.init(jax.random.PRNGKey(0))
    batch = make_batch(config_for(spec, 8, 32), 0)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    s1, m1 = jax.jit(make_pipeline_train_step(
        m_ref, TrainConfig(n_micro=4), mesh))(init_train_state(params), batch)
    s2, m2 = jax.jit(make_pipeline_train_step(
        m_pal, TrainConfig(n_micro=4), mesh))(init_train_state(params), batch)

    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    assert dl < 5e-3, f"loss diverged: {dl}"
    # first-moment norms: the update direction each backend produced
    norms = [(float(jnp.linalg.norm(a.astype(jnp.float32))),
              float(jnp.linalg.norm(jax.device_get(b).astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(s1.m), jax.tree.leaves(s2.m))]
    worst = max(abs(a - b) / max(a, 1e-6) for a, b in norms)
    assert worst < 2e-2, f"first-moment norms diverged: {worst}"
    print("PALLAS_3D_OK", dl, worst)
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_pallas_backend_reproduces_reference_3d_step():
    """pp2 × dp2 × tp2 (interpret mode): one pipeline step with
    backend="pallas" reproduces the reference step's loss and first-moment
    norms — the tentpole acceptance."""
    r = _run(PALLAS_3D)
    assert "PALLAS_3D_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


# ---------------------------------------------------------------------------
# Memory model: flash drops exactly the s² term
# ---------------------------------------------------------------------------

def test_flash_drops_exactly_the_score_term():
    pytest.importorskip(
        "hypothesis",
        reason="property test needs hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    from repro.configs import get_spec
    from repro.core.activations import (gqa_activation_bytes,
                                        mla_activation_bytes)
    from repro.core.parallel_config import RecomputePolicy

    mla_spec = get_spec("deepseek-v2")       # n_h = 128
    gqa_spec = get_spec("qwen2-1.5b")        # n_h = 12, n_kv = 2

    @settings(max_examples=60, deadline=None)
    @given(b=st.integers(1, 8), s=st.sampled_from([128, 512, 4096]),
           tp=st.sampled_from([1, 2, 4]),
           impl=st.sampled_from(["flash", "pallas"]))
    def invariant(b, s, tp, impl):
        for spec, fn in ((mla_spec, mla_activation_bytes),
                         (gqa_spec, gqa_activation_bytes)):
            kw = dict(tp=tp, sp=1, cp=1)
            scores = 5 * b * spec.n_h * s * s // tp   # tp | n_h for both specs
            naive = fn(spec, b, s, recompute=RecomputePolicy.NONE,
                       attn_impl="naive", **kw)
            flash = fn(spec, b, s, recompute=RecomputePolicy.NONE,
                       attn_impl=impl, **kw)
            # AC-None: flash subtracts the score term and nothing else
            assert naive - flash == scores, (spec.name, naive, flash, scores)
            assert flash <= naive
            # SELECTIVE already dropped it — flash must not double-subtract
            sel_n = fn(spec, b, s, recompute=RecomputePolicy.SELECTIVE,
                       attn_impl="naive", **kw)
            sel_f = fn(spec, b, s, recompute=RecomputePolicy.SELECTIVE,
                       attn_impl=impl, **kw)
            assert sel_f == sel_n == flash
            # FULL keeps only the 2bsh boundary — impl-independent
            full_n = fn(spec, b, s, recompute=RecomputePolicy.FULL,
                        attn_impl="naive", **kw)
            full_f = fn(spec, b, s, recompute=RecomputePolicy.FULL,
                        attn_impl=impl, **kw)
            assert full_f == full_n

    invariant()


@pytest.mark.parametrize("arch,b,s,tp", [
    ("deepseek-v2", 1, 4096, 2),
    ("qwen2-1.5b", 4, 512, 4),
])
def test_flash_delta_exact_deterministic(arch, b, s, tp):
    """hypothesis-free pin of the same invariant: delta == 5·b·n_h·s²/tp."""
    from repro.configs import get_spec
    from repro.core.activations import (gqa_activation_bytes,
                                        mla_activation_bytes)
    from repro.core.notation import AttentionKind
    from repro.core.parallel_config import RecomputePolicy

    spec = get_spec(arch)
    fn = mla_activation_bytes if spec.attention == AttentionKind.MLA \
        else gqa_activation_bytes
    kw = dict(tp=tp, sp=1, cp=1)
    naive = fn(spec, b, s, recompute=RecomputePolicy.NONE,
               attn_impl="naive", **kw)
    flash = fn(spec, b, s, recompute=RecomputePolicy.NONE,
               attn_impl="flash", **kw)
    assert naive - flash == 5 * b * spec.n_h * s * s // tp


def test_estimate_memory_flash_direction():
    """End to end through estimate_memory: the flash override strictly
    reduces the activation term at AC-None and touches nothing else."""
    from repro.configs import get_spec
    from repro.core.memory_model import estimate_memory
    from repro.core.parallel_config import (ParallelConfig, RecomputePolicy,
                                            ZeROStage)

    spec = get_spec("deepseek-v2")
    cfg = ParallelConfig(dp=4, tp=2, pp=4, ep=1, etp=1, sp=True,
                         zero=ZeROStage.OS, recompute=RecomputePolicy.NONE,
                         micro_batch=1, seq_len=4096)
    naive = estimate_memory(spec, cfg)
    flash = estimate_memory(spec, cfg, attn_impl="flash")
    assert flash.activations < naive.activations
    assert flash.params == naive.params
    assert flash.grads == naive.grads
    assert flash.optimizer == naive.optimizer
