"""TP divisibility guards (analytic + executor-facing) and the planner's
``runnable`` marking."""

import dataclasses

import pytest

from repro.configs import get_spec
from repro.core import (ParallelConfig, RecomputePolicy, ZeROStage,
                        executor_runnable, plan, tp_violations)
from repro.core.activations import (dense_mlp_activation_bytes,
                                    gqa_activation_bytes)
from repro.core.parallel_config import RecomputePolicy as RP


def _cfg(**kw):
    base = dict(dp=4, tp=2, pp=1, ep=1, etp=1, sp=True,
                zero=ZeROStage.OS_G, recompute=RecomputePolicy.NONE,
                micro_batch=1, seq_len=4096)
    base.update(kw)
    return ParallelConfig(**base)


def test_tp_violations_lists_offending_dims():
    qwen = get_spec("qwen2-1.5b")
    assert tp_violations(qwen, 2) == []
    bad = tp_violations(qwen, 5)              # n_h=12, n_kv=2, h_ff=8960
    assert any("n_h" in b for b in bad)
    assert any("n_kv" in b for b in bad)
    hymba = get_spec("hymba-1.5b")            # n_h=25
    assert any("n_h" in b for b in tp_violations(hymba, 2))


def test_indivisible_tp_warns_and_degrades():
    """hymba's n_h=25 at tp=2 previously floor-divided every term; now the
    guard warns loudly and degrades only what the runtime cannot shard:
    head-indexed score tensors fall to gcd(25, 2)=1 (replicated) and the
    n_kv=5 K/V to gcd(5, 2)=1, while the fused 25·64 qkv columns still
    split 2 ways."""
    hymba = get_spec("hymba-1.5b")
    b, s, d = 1, 1024, hymba.d_head
    with pytest.warns(RuntimeWarning, match="n_h=25"):
        got = gqa_activation_bytes(hymba, b, s, tp=2, sp=1, cp=1,
                                   recompute=RP.NONE)
    expect = (3 * b * s * hymba.h
              + 2 * 2 * b * s * hymba.n_h * d // 2      # Q + ctx, fused /2
              + 2 * 2 * b * s * hymba.n_kv * d          # K,V gcd(5,2)=1
              + 5 * b * hymba.n_h * s * s)              # scores gcd(25,2)=1
    assert got == expect
    tp1 = gqa_activation_bytes(hymba, b, s, tp=1, sp=1, cp=1,
                               recompute=RP.NONE)
    assert got < tp1                # fused splits still help ...
    assert got > tp1 // 2           # ... but scores no longer silently //2
    with pytest.warns(RuntimeWarning, match="h_ff"):
        dense_mlp_activation_bytes(
            dataclasses.replace(get_spec("qwen2-1.5b"), h_ff=8961),
            1, 1024, tp=2, sp=1, cp=1, recompute=RP.NONE)


def test_kv_clamp_in_activation_bytes():
    """K/V activations shard at most n_kv ways: qwen2 (n_kv=2) at tp=4
    must count K,V divided by 2, not 4."""
    spec = get_spec("qwen2-1.5b")             # n_h=12 % 4 = 0, n_kv=2
    b, s, d = 2, 1024, spec.d_head
    got = gqa_activation_bytes(spec, b, s, tp=4, sp=1, cp=1,
                               recompute=RP.NONE)
    kv_term = 2 * 2 * b * s * spec.n_kv * d // 2       # clamped at n_kv
    kv_wrong = 2 * 2 * b * s * spec.n_kv * d // 4
    scores = 5 * b * spec.n_h * s * s // 4
    q_ctx = 2 * 2 * b * s * spec.n_h * d // 4
    fixed = 3 * b * s * spec.h                          # sp=1 terms
    assert got == fixed + q_ctx + kv_term + scores
    assert got != fixed + q_ctx + kv_wrong + scores


def test_executor_runnable_marking():
    qwen = get_spec("qwen2-1.5b")
    ok, why = executor_runnable(qwen, _cfg(tp=2, zero=ZeROStage.OS))
    assert ok, why
    # ZeRO-3 is executor-real since the gather-on-use path landed
    ok, why = executor_runnable(qwen, _cfg(tp=2, zero=ZeROStage.OS_G_PARAMS))
    assert ok, why
    ok, why = executor_runnable(get_spec("rwkv6-1.6b"), _cfg(tp=1))
    assert not ok and "SSM" in why
    ds = get_spec("deepseek-v3")
    # PR 5: ep == tp MoE configs ARE runnable (a2a dispatch over 'model');
    # only degrees the whole-axis a2a group cannot place stay estimator-only
    ok, why = executor_runnable(ds, _cfg(tp=2, ep=2))
    assert ok, why
    ok, why = executor_runnable(ds, _cfg(tp=4, ep=2, dp=2))
    assert not ok and "estimator-only" in why
    ok, why = executor_runnable(ds, _cfg(tp=2, ep=1))
    assert ok, why
    hymba = get_spec("hymba-1.5b")
    ok, why = executor_runnable(
        dataclasses.replace(hymba, ssm=None), _cfg(tp=2))
    assert not ok and "n_h" in why


def test_plan_marks_tp_and_zero_configs_runnable():
    """Acceptance: plan() surfaces tp>1 / ZeRO-sharded configs the 3D
    executor can actually run, with runnable=True."""
    spec = get_spec("qwen2-1.5b")
    entries = plan(spec, world_size=8, hbm_bytes=96 * 2 ** 30,
                   seq_len=4096, top_k=50, max_tp=4)
    runnable_tp = [e for e in entries
                   if e.runnable and e.cfg.tp > 1
                   and e.cfg.zero != ZeROStage.NONE]
    assert runnable_tp, "no runnable tp>1 + ZeRO configs surfaced"
    # ZeRO-3 configs rank as runnable with a finite predicted step time
    # (the gather-on-use path) — acceptance for the os+g+params executor
    z3 = [e for e in entries
          if e.runnable and e.cfg.zero == ZeROStage.OS_G_PARAMS]
    assert z3, "no runnable ZeRO-3 configs surfaced"
    assert any(e.predicted_step_s is not None
               and e.predicted_step_s > 0 for e in z3)
    # an SSM family is never runnable by the pipeline executor
    entries = plan(get_spec("rwkv6-1.6b"), world_size=8,
                   hbm_bytes=96 * 2 ** 30, seq_len=4096, top_k=10)
    assert entries and all(not e.runnable for e in entries)
