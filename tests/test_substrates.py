"""Substrate integration tests: data pipeline, checkpointing, serving,
planner, report rendering, kv-cache model."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.core import (PAPER_CONFIG, ParallelConfig, RecomputePolicy,
                        ZeROStage, estimate_memory, kv_cache_bytes,
                        min_memory_config, plan)
from repro.data.synthetic import SyntheticConfig, config_for, make_batch
from repro.models import build_model
from repro.optim.adamw import init_train_state
from repro.serving import ServeConfig, serve_requests


def test_synthetic_batches_deterministic():
    cfg = SyntheticConfig(batch=4, seq_len=64, vocab=1000, seed=7)
    b1 = make_batch(cfg, step=3)
    b2 = make_batch(cfg, step=3)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, step=4)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000


def test_synthetic_has_copy_structure():
    cfg = SyntheticConfig(batch=8, seq_len=256, vocab=5000, seed=0,
                          repeat_prob=0.3)
    t = np.asarray(make_batch(cfg, 0)["tokens"])
    frac = (t[:, 8:] == t[:, :-8]).mean()
    assert frac > 0.2, frac      # learnable signal present


def test_checkpoint_roundtrip():
    from repro.checkpoint import latest_step, restore, save
    spec = get_spec("qwen2-1.5b", smoke=True)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    with tempfile.TemporaryDirectory() as d:
        save(d, 42, state)
        assert latest_step(d) == 42
        zero_state = jax.tree.map(jnp.zeros_like, state)
        back = restore(d, 42, zero_state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_serve_requests_greedy_deterministic():
    spec = get_spec("gemma-2b", smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.ones((2, 4), jnp.int32)
    a = serve_requests(model, params, prompts,
                       ServeConfig(max_new_tokens=8), cache_len=32)
    b = serve_requests(model, params, prompts,
                       ServeConfig(max_new_tokens=8), cache_len=32)
    assert jnp.array_equal(a, b)
    assert a.shape == (2, 8)


def test_planner_finds_feasible_configs():
    spec = get_spec("qwen2-1.5b")
    entries = plan(spec, world_size=64, hbm_bytes=32 * 2**30, seq_len=4096,
                   top_k=5)
    assert entries, "1.5B model must fit 64x32GiB somehow"
    for e in entries:
        assert e.estimate.total <= 32 * 2**30
        assert e.cfg.world_size == 64


def test_planner_min_memory_is_min():
    spec = get_spec("gemma-2b")
    best = min_memory_config(spec, world_size=32, seq_len=4096)
    assert best is not None
    # spot-check: it beats a handful of arbitrary configs
    for cfg in [ParallelConfig(dp=32), ParallelConfig(dp=8, tp=4),
                ParallelConfig(dp=16, tp=2, zero=ZeROStage.OS)]:
        assert best.estimate.total <= estimate_memory(spec, cfg).total


def test_kv_cache_bytes_mla_advantage():
    ds = get_spec("deepseek-v3")
    cfg = ParallelConfig(dp=1, tp=1, pp=1, micro_batch=1, seq_len=4096)
    mla = kv_cache_bytes(ds, cfg)
    mha = kv_cache_bytes(dataclasses.replace(
        ds, attention=__import__("repro.core.notation",
                                 fromlist=["AttentionKind"]
                                 ).AttentionKind.MHA, mla=None), cfg)
    assert mha / mla > 50       # the MLA latent-cache advantage


def test_kv_cache_sliding_window_caps():
    spec = get_spec("qwen2-1.5b")
    long_cfg = ParallelConfig(dp=1, tp=1, pp=1, micro_batch=1,
                              seq_len=524288)
    unbounded = kv_cache_bytes(spec, long_cfg)
    capped = kv_cache_bytes(dataclasses.replace(spec, sliding_window=8192),
                            long_cfg)
    assert capped * 32 < unbounded


def test_report_renders():
    from repro.core import report
    spec = get_spec("deepseek-v3")
    for fn in (report.render_table3, lambda s: report.render_table4(s, 16)):
        out = fn(spec)
        assert isinstance(out, str) and len(out) > 100
    for fn in (report.render_table6, report.render_table8,
               report.render_table10, report.render_full_estimate):
        out = fn(spec, PAPER_CONFIG)
        assert isinstance(out, str) and len(out) > 50


def test_remat_policies_same_loss():
    """AC none/selective/full change memory, never numerics."""
    from repro.models.transformer import ModelOptions
    from repro.data.synthetic import config_for, make_batch
    spec = get_spec("minitron-4b", smoke=True)
    batch = make_batch(config_for(spec, 2, 32), 0)
    losses = []
    for rc in RecomputePolicy:
        model = build_model(spec, ModelOptions(recompute=rc))
        params = model.init(jax.random.PRNGKey(0))
        loss, _ = jax.jit(model.loss)(params, batch)
        losses.append(float(loss))
    assert max(losses) - min(losses) < 1e-3, losses
