"""Step-bench harness unit tests (no jax, no subprocess, no devices):

* ``repro.train.timing.time_callable`` — median-of-k is monotone under an
  injected sleep, warmup calls never land in the samples, bad arguments
  raise;
* ``repro.train.timing.merge_rows`` — newest-wins dedupe on the full
  config key, stable sorted output, schema growth keeps old rows distinct;
* ``core.steptime.mfu`` — hand-computed dense case (tiny spec, FLOPs done
  by hand from the PaLM 3× convention);
* ``benchmarks.step_bench.check_direction`` — accepts a consistent
  ranking, flags an inverted one, treats close predictions as ties, and
  never compares across chunk granularities or mesh cells (dp/ep/zero are
  part of the cell key);
* ``benchmarks.step_bench.check_convergence`` — the overlap gate: zb1p
  must measure within the tie band of 1f1b, and every pp>1 row must skip
  idle rank-ticks (``ticks_active < ticks_total``).

The measured grid itself runs in ``benchmarks/step_bench.py`` (CI's
step-bench-smoke job); these tests pin the harness logic that the
committed BENCH_step.json rows and the CI direction gate depend on.
"""

import time

import pytest

from benchmarks.step_bench import (KEY_FIELDS, check_convergence,
                                   check_direction)
from repro.train.timing import TimingResult, merge_rows, time_callable


# ---------------------------------------------------------------------------
# time_callable
# ---------------------------------------------------------------------------

def test_median_monotone_under_injected_sleep():
    """A callable that sleeps 2x as long must report >= the median of the
    faster one — the basic sanity the whole benchmark rests on."""
    fast = time_callable(lambda: time.sleep(0.002), iters=5, warmup=1,
                         block=False)
    slow = time_callable(lambda: time.sleep(0.008), iters=5, warmup=1,
                         block=False)
    assert slow.median_s > fast.median_s
    assert fast.median_s >= 0.002 and slow.median_s >= 0.008
    assert len(fast.times_s) == 5


def test_warmup_not_in_samples():
    """First (compile-like) call is expensive; it must land in warmup_s,
    never in the timed samples or the median."""
    calls = []

    def fn():
        calls.append(None)
        time.sleep(0.05 if len(calls) == 1 else 0.001)

    r = time_callable(fn, iters=4, warmup=1, block=False)
    assert len(calls) == 5                 # 1 warmup + 4 timed
    assert r.warmup_s >= 0.05
    assert r.median_s < 0.05 / 2
    assert max(r.times_s) < 0.05 / 2


def test_time_callable_passes_args_and_validates():
    seen = []
    r = time_callable(lambda a, b: seen.append((a, b)), 1, 2,
                      iters=2, warmup=0, block=False)
    assert seen == [(1, 2)] * 2 and isinstance(r, TimingResult)
    with pytest.raises(ValueError):
        time_callable(lambda: None, iters=0, block=False)
    with pytest.raises(ValueError):
        time_callable(lambda: None, warmup=-1, block=False)


def test_timing_result_stats():
    r = TimingResult(times_s=(3.0, 1.0, 2.0), warmup_s=0.0)
    assert r.median_s == 2.0 and r.min_s == 1.0
    assert abs(r.mean_s - 2.0) < 1e-12 and r.median_us == 2e6


# ---------------------------------------------------------------------------
# merge_rows (the BENCH_*.json dedupe)
# ---------------------------------------------------------------------------

def _row(schedule, pp, median):
    r = {k: None for k in KEY_FIELDS}
    r.update(schedule=schedule, pp=pp, arch="a", median_s=median)
    return r


def test_merge_rows_newest_wins():
    old = [_row("1f1b", 2, 1.0), _row("zb1p", 2, 2.0)]
    new = [_row("zb1p", 2, 1.5), _row("dualpipe", 4, 3.0)]
    merged = merge_rows(old, new, KEY_FIELDS)
    assert len(merged) == 3
    by = {(r["schedule"], r["pp"]): r for r in merged}
    assert by[("zb1p", 2)]["median_s"] == 1.5       # re-run replaced the row
    assert by[("1f1b", 2)]["median_s"] == 1.0       # untouched row survives
    # deterministic order: stable re-runs produce minimal JSON diffs
    assert merged == merge_rows(old, new, KEY_FIELDS)


def test_merge_rows_missing_key_fields_stay_distinct():
    """A row written before a key field existed must not be clobbered by a
    row that has it (both keys stringify differently)."""
    old = [{"schedule": "1f1b", "median_s": 1.0}]
    new = [dict(_row("1f1b", 2, 9.9))]
    assert len(merge_rows(old, new, KEY_FIELDS)) == 2


# ---------------------------------------------------------------------------
# MFU, hand-computed
# ---------------------------------------------------------------------------

def test_mfu_hand_computed_dense():
    """Tiny dense spec, FLOPs by hand: proj 2/param/token, attention
    4·t·s·n_h·d, head 2·t·h·V; step = 3× fwd; MFU = step_flops /
    (t · peak · n_dev)."""
    from repro.core.notation import FamilyKind, ModelSpec
    from repro.core.steptime import mfu, model_fwd_flops, step_flops

    spec = ModelSpec(name="tiny", family=FamilyKind.DENSE, n_layers=2, h=4,
                     n_h=2, n_kv=2, d_head=2, h_ff=8, vocab=16)
    t, s = 8, 8
    # per layer: qkvo 4·h·(n_h·d) = 4·4·4 = 64 params, mlp 3·h·h_ff = 96
    # params -> proj flops 2·t·160; attn 4·t·s·n_h·d = 4·t·8·4
    layer = 2 * t * (4 * 4 * 4 + 3 * 4 * 8) + 4 * t * s * 2 * 2
    fwd = 2 * layer + 2 * t * 4 * 16          # 2 layers + head
    assert model_fwd_flops(spec, t, s) == pytest.approx(fwd)
    assert step_flops(spec, t, s) == pytest.approx(3 * fwd)
    assert mfu(2.0, spec, t, s, peak_flops_per_s=100.0, n_devices=4) == \
        pytest.approx(3 * fwd / (2.0 * 100.0 * 4))
    with pytest.raises(ValueError):
        mfu(0.0, spec, t, s, peak_flops_per_s=100.0)
    with pytest.raises(ValueError):
        mfu(1.0, spec, t, s, peak_flops_per_s=0.0)


# ---------------------------------------------------------------------------
# check_direction (the CI gate)
# ---------------------------------------------------------------------------

def _bench_row(schedule, measured, predicted, *, pp=2, n_chunks=1,
               dp=2, ep=1, zero="os", **extra):
    row = {"arch": "a", "schedule": schedule, "pp": pp, "dp": dp, "tp": 2,
           "sp": False, "ep": ep, "zero": zero, "n_micro": 4,
           "n_chunks": n_chunks, "batch": 8, "seq_len": 32,
           "median_s": measured, "predicted_s": predicted}
    row.update(extra)
    return row


def test_direction_ok_on_consistent_ranking():
    rows = [_bench_row("1f1b", 1.0, 1.0), _bench_row("zb1p", 1.2, 1.18),
            _bench_row("dualpipe", 1.5, 1.4)]
    assert check_direction(rows) == []


def test_direction_fails_loudly_on_inversion():
    """Predicted says zb1p clearly faster than dualpipe; measured says the
    opposite -> exactly one violation naming both schedules."""
    rows = [_bench_row("zb1p", 1.6, 1.0), _bench_row("dualpipe", 1.2, 1.4)]
    bad = check_direction(rows)
    assert len(bad) == 1
    assert "zb1p" in bad[0] and "dualpipe" in bad[0]


def test_direction_close_predictions_are_ties():
    """Inside the min_gap band either measured order passes — CPU noise
    cannot flake the gate."""
    rows = [_bench_row("1f1b", 1.3, 1.00), _bench_row("zb1p", 1.0, 1.05)]
    assert check_direction(rows, min_gap=0.10) == []
    # ...but the same pair fails once the predicted gap clears the band
    rows = [_bench_row("1f1b", 1.3, 1.00), _bench_row("zb1p", 1.0, 1.25)]
    assert len(check_direction(rows, min_gap=0.10)) == 1


def test_direction_never_compares_across_chunk_granularity():
    """interleaved (n_chunks=2) lives in its own cell: half-size chunks
    make its per-tick cost incomparable on an overhead-dominated host."""
    rows = [_bench_row("interleaved", 0.9, 2.0, n_chunks=2),
            _bench_row("dualpipe", 1.5, 1.0)]
    assert check_direction(rows) == []


def test_direction_separates_pp_cells():
    rows = [_bench_row("1f1b", 1.0, 1.0, pp=2),
            _bench_row("zb1p", 0.5, 2.0, pp=4)]
    assert check_direction(rows) == []


def test_direction_separates_mesh_cells():
    """dp/ep/zero are part of the cell key: a zb1p row on a different mesh
    (or ZeRO stage) is never ranked against a 1f1b row — even when their
    (pp, tp, sp) coordinates coincide."""
    rows = [_bench_row("1f1b", 1.0, 1.0, dp=2),
            _bench_row("zb1p", 2.0, 0.5, dp=1)]
    assert check_direction(rows) == []
    rows = [_bench_row("1f1b", 1.0, 1.0, zero="os"),
            _bench_row("zb1p", 2.0, 0.5, zero="os+g")]
    assert check_direction(rows) == []
    rows = [_bench_row("1f1b", 1.0, 1.0, ep=1),
            _bench_row("zb1p", 2.0, 0.5, ep=2)]
    assert check_direction(rows) == []
    # same mesh -> the inversion is caught
    rows = [_bench_row("1f1b", 1.0, 1.0), _bench_row("zb1p", 2.0, 0.5)]
    assert len(check_direction(rows)) == 1


# ---------------------------------------------------------------------------
# check_convergence (the overlap gate)
# ---------------------------------------------------------------------------

def _conv_row(schedule, measured, *, pp=2, total=20, active=16, **extra):
    return _bench_row(schedule, measured, measured, pp=pp,
                      ticks_total=total, ticks_active=active, **extra)


def test_convergence_accepts_zb_at_or_below_1f1b():
    rows = [_conv_row("1f1b", 1.0), _conv_row("zb1p", 0.9)]
    assert check_convergence(rows) == []
    # inside the tie band is fine too
    rows = [_conv_row("1f1b", 1.0), _conv_row("zb1p", 1.08)]
    assert check_convergence(rows) == []


def test_convergence_flags_zb_above_band():
    rows = [_conv_row("1f1b", 1.0), _conv_row("zb1p", 1.2)]
    bad = check_convergence(rows)
    assert len(bad) == 1 and "zb1p" in bad[0]


def test_convergence_requires_skipped_ticks():
    rows = [_conv_row("1f1b", 1.0, total=20, active=20)]
    bad = check_convergence(rows)
    assert len(bad) == 1 and "ticks_active" in bad[0]
    # pp=1 rows are exempt (no pipeline, nothing to skip)
    assert check_convergence([_conv_row("1f1b", 1.0, pp=1,
                                        total=4, active=4)]) == []
    # rows predating the overlap engine fail loudly, not silently
    legacy = _bench_row("1f1b", 1.0, 1.0)
    assert len(check_convergence([legacy])) == 1


def test_convergence_separates_mesh_cells():
    rows = [_conv_row("1f1b", 1.0, dp=2), _conv_row("zb1p", 5.0, dp=1)]
    assert check_convergence(rows) == []
