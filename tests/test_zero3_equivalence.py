"""ZeRO-3 (os+g+params) executor equivalence and invariants.

The gather-on-use path (``parallel.tp.gather_params`` + the DP stage specs
from ``parallel.sharding.zero3_stage_specs``) must be a pure memory
optimisation: the pp2×dp2×tp2 step under ``os+g+params`` reproduces the
``os+g`` step's loss and post-update master params to bf16-accumulation
tolerance, while each device holds ~1/dp of the bf16 working params.

Needs >1 fake device set before jax initialises — subprocess with XLA_FLAGS
(same harness as test_pipeline_3d).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    def check(tag, m1, s1, m2, s2, tol_loss=5e-3, tol_p=2e-2):
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < tol_loss, f"{tag}: loss diverged {dl}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < tol_p, f"{tag}: master params diverged {worst}"
        print(f"{tag}_OK", dl, worst)
""")

Z3_EQUIVALENCE = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    ref_step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        zero=ZeROStage.OS_G)
    s1, m1 = jax.jit(ref_step)(state, batch)
    z3_step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                       zero=ZeROStage.OS_G_PARAMS)
    s2, m2 = jax.jit(z3_step)(state, batch)
    check("Z3_VS_OSG_PP2_DP2_TP2", m1, s1, m2, s2)
""")

Z3_STATE_INVARIANT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.parallel.sharding import state_shardings

    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    dp = mesh.shape["data"]

    def dev0_bytes(tree):
        return sum(x.addressable_shards[0].data.nbytes
                   for x in jax.tree.leaves(tree))

    sh_osg = state_shardings(state, mesh, ZeROStage.OS_G)
    sh_z3 = state_shardings(state, mesh, ZeROStage.OS_G_PARAMS)
    st_osg = jax.device_put(state, sh_osg)
    st_z3 = jax.device_put(state, sh_z3)
    # os+g leaves the bf16 working copy replicated over DP; ZeRO-3 shards
    # it — per-device param bytes drop to ~1/dp (every smoke-model leaf
    # admits a DP dim, so the ratio is exact)
    full = dev0_bytes(st_osg.params)
    shard = dev0_bytes(st_z3.params)
    ratio = shard / full
    assert abs(ratio - 1.0 / dp) < 0.05, ratio
    # optimizer state shards identically under both stages
    for field in ("master", "m", "v"):
        assert dev0_bytes(getattr(st_z3, field)) == \
            dev0_bytes(getattr(st_osg, field)), field
    print(f"Z3_STATE_INVARIANT_OK {ratio:.3f} (dp={dp})")
""")


Z3_CHECKPOINT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import latest_step, restore, save
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.parallel.sharding import state_shardings

    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    sh = state_shardings(state, mesh, ZeROStage.OS_G_PARAMS)
    st = jax.device_put(state, sh)
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, st)
        assert latest_step(d) == 7
        man = json.load(open(os.path.join(d, "step_00000007",
                                          "manifest.json")))
        # DP/TP-sharded leaves were gathered to full arrays at save time
        assert any(v["gathered"] for v in man["leaves"].values())
        like = jax.device_put(jax.tree.map(jnp.zeros_like, state), sh)
        back = restore(d, 7, like)
        for a, b, l in zip(jax.tree.leaves(state), jax.tree.leaves(back),
                           jax.tree.leaves(like)):
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(a), np.float32),
                np.asarray(jax.device_get(b), np.float32))
            assert b.sharding == l.sharding     # re-adopted the Z3 layout
    print("Z3_CHECKPOINT_OK")
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_zero3_reproduces_osg_step():
    """pp2 × dp2 × tp2: the ZeRO-3 gather-on-use step reproduces the os+g
    step to bf16 tolerance (the tentpole acceptance)."""
    r = _run(Z3_EQUIVALENCE)
    assert "Z3_VS_OSG_PP2_DP2_TP2_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_zero3_param_sharding_invariant():
    """Each DP shard holds ~1/dp of the bf16 working-param bytes under
    ZeRO-3 (measured from device buffers), with optimizer state unchanged
    vs os+g."""
    r = _run(Z3_STATE_INVARIANT)
    assert "Z3_STATE_INVARIANT_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_zero3_checkpoint_roundtrip():
    """A ZeRO-3 DP-sharded TrainState checkpoints via gather-on-save (the
    manifest marks gathered leaves) and restores back onto its sharded
    layout with identical values."""
    r = _run(Z3_CHECKPOINT)
    assert "Z3_CHECKPOINT_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_zero_ladder_monotone_per_component():
    """Walking up the ZeRO ladder never increases any state component —
    including at DP degrees that don't divide the parameter count (the
    ceil-rounding regression: floor division made a coarser shard look
    *smaller* than a finer one)."""
    import pytest
    pytest.importorskip(
        "hypothesis",
        reason="property test needs hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    from repro.configs import get_spec
    from repro.core.parallel_config import (ParallelConfig, RecomputePolicy,
                                            ZeROStage)
    from repro.core.zero import zero_memory

    spec = get_spec("qwen2-1.5b")

    @settings(max_examples=40, deadline=None)
    @given(dp=st.integers(1, 64), tp=st.sampled_from([1, 2, 4]),
           pp=st.sampled_from([1, 2, 4]))
    def invariant(dp, tp, pp):
        cfg = ParallelConfig(
            dp=dp, tp=tp, pp=pp, ep=1, etp=1, sp=False,
            zero=ZeROStage.NONE, recompute=RecomputePolicy.NONE,
            micro_batch=1, seq_len=4096)
        ladder = [zero_memory(spec, dataclasses.replace(cfg, zero=z))
                  for z in ZeROStage]
        for a, b in zip(ladder, ladder[1:]):
            assert b.params <= a.params
            assert b.grads <= a.grads
            assert b.optimizer <= a.optimizer
            assert b.total <= a.total

    invariant()
