"""Schedule tick-sequence invariants, for every schedule × (pp, v, M):

* every microbatch is forwarded exactly once per model chunk (virtual
  stage), and backwarded exactly once;
* each backward runs at/after its forward; cross-rank dependencies respect
  the one-tick transfer latency; one op per rank per canonical tick;
* the canonical peak in-flight matches the closed forms in
  ``core.schedule_in_flight`` (the formulas ``estimate_memory`` and the
  planner consume);
* the executor tables route every boundary tensor to the slot its consumer
  reads, without clobbering a live slot (symbolic replay of the tick loop).

A deterministic grid always runs; hypothesis widens the search when
installed (CI installs requirements-dev.txt).
"""

import numpy as np
import pytest

from repro.core.schedules import (PipelineSchedule, exec_tick_times,
                                  make_schedule, schedule_placement)
from repro.core.activations import one_f1b_in_flight, schedule_in_flight
from repro.train.schedules import build_exec_tables

GRID = (
    [("1f1b", pp, m, 1) for pp in (1, 2, 3, 4) for m in (1, 2, 5, 8)]
    + [("interleaved", pp, m, v)
       for pp in (2, 3, 4) for v in (2, 3) for m in (pp, 2 * pp, 4 * pp)]
    + [("dualpipe", pp, m, 2) for pp in (2, 3, 4, 5) for m in (1, 2, 5, 8)]
)


def _closed_form(name, pp, m, v):
    return [schedule_in_flight(pp, r, m, schedule=name, n_chunks=v)
            for r in range(pp)]


def _check_exec_routing(sched: PipelineSchedule) -> None:
    """Replay the executor tables symbolically: buffers hold (micro, stage)
    tags; every read must see the tag the schedule promises."""
    tab = build_exec_tables(sched)
    pp, G, M = tab.pp, tab.n_stages, tab.n_micro
    own = [[sched.owner(g, m) for g in range(G)] for m in range(M)]
    stage_at = {}
    for m in range(M):
        for g in range(G):
            stage_at[(m,) + own[m][g]] = g
    xbuf = [[None] * (tab.n_chunks * tab.x_slots) for _ in range(pp)]
    gbuf = [[None] * (tab.n_chunks * tab.g_slots) for _ in range(pp)]
    fouts, bouts = {}, {}
    for t in range(tab.T):
        for r in range(pp):
            if tab.f_act[t, r] > 0:
                m, c = int(tab.f_micro[t, r]), int(tab.f_chunk[t, r])
                g = stage_at[(m, r, c)]
                if g > 0:
                    assert xbuf[r][int(tab.f_xidx[t, r])] == (m, g - 1), \
                        f"t{t} r{r}: F({m},{g}) read a stale boundary input"
                fouts[(t, r)] = (m, g)
            if tab.b_act[t, r] > 0:
                m, c = int(tab.b_micro[t, r]), int(tab.b_chunk[t, r])
                g = stage_at[(m, r, c)]
                if g > 0:
                    assert xbuf[r][int(tab.b_xidx[t, r])] == (m, g - 1)
                if g < G - 1:
                    assert gbuf[r][int(tab.b_gidx[t, r])] == (m, g + 1), \
                        f"t{t} r{r}: B({m},{g}) read a stale cotangent"
                bouts[(t, r)] = (m, g)
        for r in range(pp):
            if tab.rfd_act[t, r] > 0:
                assert tab.fsend_down[t, (r - 1) % pp] > 0
                xbuf[r][int(tab.rfd_idx[t, r])] = fouts[(t, (r - 1) % pp)]
            if tab.rfu_act[t, r] > 0:
                assert tab.fsend_up[t, (r + 1) % pp] > 0
                xbuf[r][int(tab.rfu_idx[t, r])] = fouts[(t, (r + 1) % pp)]
            if tab.rgd_act[t, r] > 0:
                assert tab.bsend_down[t, (r - 1) % pp] > 0
                gbuf[r][int(tab.rgd_idx[t, r])] = bouts[(t, (r - 1) % pp)]
            if tab.rgu_act[t, r] > 0:
                assert tab.bsend_up[t, (r + 1) % pp] > 0
                gbuf[r][int(tab.rgu_idx[t, r])] = bouts[(t, (r + 1) % pp)]


@pytest.mark.parametrize("name,pp,m,v", GRID)
def test_schedule_invariants(name, pp, m, v):
    if name != "1f1b" and pp < 2:
        pytest.skip("multi-chunk schedules need pp >= 2")
    sched = make_schedule(name, pp, m, n_chunks=v)
    sched.check()   # exactly-once F/B per (micro, chunk), deps, capacity
    peaks = [sched.rank_peak_in_flight(r) for r in range(pp)]
    assert peaks == _closed_form(name, pp, m, v), \
        f"{name} pp={pp} M={m} v={v}: simulated {peaks}"


@pytest.mark.parametrize("name,pp,m,v", [g for g in GRID if g[1] > 1])
def test_exec_tables_route_correctly(name, pp, m, v):
    _check_exec_routing(make_schedule(name, pp, m, n_chunks=v))


def test_1f1b_exec_timing_nests_canonical_order():
    """The executor timeline preserves the canonical per-rank op order, and
    its boundary-input ring stays within PR 1's 1F1B bound min(M, 2pp-1)
    (the executor packs one F and one B per tick, so residency between a
    boundary input's arrival and its backward can exceed the canonical
    one-op-per-tick count, but never the classic ring bound)."""
    for pp, m in [(2, 4), (4, 4), (4, 8)]:
        sched = make_schedule("1f1b", pp, m)
        tab = build_exec_tables(sched)
        assert 1 <= tab.x_slots <= min(m, 2 * pp - 1)
        assert tab.g_slots == 1
        times = exec_tick_times(sched)
        for r in range(pp):
            f_ts = [times[("F", mm, r)] for mm in range(m)]
            b_ts = [times[("B", mm, r)] for mm in range(m)]
            assert f_ts == sorted(f_ts) and b_ts == sorted(b_ts)


def test_dualpipe_profile_flat_and_duplicated():
    """DualPipe's signature: every rank ≈ pp+1 in flight, every model chunk
    placed on two ranks."""
    pp, m = 4, 8
    sched = make_schedule("dualpipe", pp, m)
    assert [sched.rank_peak_in_flight(r) for r in range(pp)] == [pp + 1] * pp
    placement = schedule_placement("dualpipe", pp, 2)
    owners = {}
    for r, row in enumerate(placement):
        for g in row:
            owners.setdefault(g, []).append(r)
    assert all(len(rs) == 2 for rs in owners.values())


def test_one_f1b_in_flight_compat():
    assert [one_f1b_in_flight(4, s) for s in range(4)] == [4, 3, 2, 1]
    assert one_f1b_in_flight(4, 0, n_micro=2) == 2
    with pytest.raises(ValueError):
        one_f1b_in_flight(4, 4)


def test_interleaved_needs_pp_multiple():
    with pytest.raises(ValueError):
        make_schedule("interleaved", 4, 6, n_chunks=2)
    with pytest.raises(ValueError):
        make_schedule("interleaved", 2, 4, n_chunks=1)


@pytest.mark.parametrize("name,pp,m,v", [g for g in GRID if g[1] > 1]
                         + [("zb1p", pp, m, 1)
                            for pp in (2, 3, 4) for m in (2, 5, 8)])
def test_predicted_ticks_match_exec_tables(name, pp, m, v):
    """Regression: ``predict_step_time``'s tick count is exactly the
    executor table height (the pre-overlap model priced zb1p as
    ``exec_ticks(1f1b) + 1``, W riding B's tick — now W ticks are real),
    and its per-(tick, rank) activity agrees with the tables: the active
    cell count equals M F-ticks + M B-ticks (+ M W-ticks under zb1p) per
    (rank, chunk)."""
    from repro.configs import get_spec
    from repro.core.steptime import exec_tick_activity, predict_step_time
    spec = get_spec("qwen2-1.5b")
    sched = make_schedule(name, pp, m, n_chunks=v)
    tab = build_exec_tables(sched)
    pred = predict_step_time(spec, name, pp, m, n_chunks=v,
                             micro_batch=1, seq_len=128)
    assert pred.ticks == tab.T
    acts = np.array(exec_tick_activity(name, pp, m, n_chunks=v))
    active = (tab.f_act > 0) | (tab.b_act > 0)
    if tab.w_act is not None:
        active |= tab.w_act > 0
    assert np.array_equal(acts > 0, active)
    assert pred.ticks_active == int(active.sum())


# ---------------------------------------------------------------------------
# Property-based widening (CI installs hypothesis; skipped when absent,
# without taking the deterministic grid above down with it)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(pp=st.integers(1, 6), m=st.integers(1, 12))
    def test_hyp_1f1b(pp, m):
        sched = make_schedule("1f1b", pp, m)
        sched.check()
        assert [sched.rank_peak_in_flight(r) for r in range(pp)] == \
            [min(m, pp - r) for r in range(pp)]
        if pp > 1:
            _check_exec_routing(sched)

    @settings(max_examples=40, deadline=None)
    @given(pp=st.integers(2, 5), v=st.integers(2, 4),
           groups=st.integers(1, 3))
    def test_hyp_interleaved(pp, v, groups):
        m = pp * groups
        sched = make_schedule("interleaved", pp, m, n_chunks=v)
        sched.check()
        assert [sched.rank_peak_in_flight(r) for r in range(pp)] == \
            [min(m * v, (v - 1) * pp + 2 * (pp - r - 1) + 1)
             for r in range(pp)]
        _check_exec_routing(sched)

    @settings(max_examples=40, deadline=None)
    @given(pp=st.integers(2, 6), m=st.integers(1, 12))
    def test_hyp_dualpipe(pp, m):
        sched = make_schedule("dualpipe", pp, m)
        sched.check()
        ma, mb = (m + 1) // 2, m // 2
        assert [sched.rank_peak_in_flight(r) for r in range(pp)] == \
            [min(ma, pp - r) + min(mb, r + 1) for r in range(pp)]
        _check_exec_routing(sched)

    @settings(max_examples=60, deadline=None)
    @given(name=st.sampled_from(["1f1b", "zb1p", "interleaved", "dualpipe"]),
           pp=st.integers(2, 5), groups=st.integers(1, 3),
           v=st.integers(2, 3))
    def test_hyp_active_ticks_match_work_totals(name, pp, groups, v):
        """The overlap engine's cost model rests on this: per rank, the exec
        tables carry exactly M F-ticks and M B-ticks per owned chunk, plus
        M W-ticks under zb1p (and zero W otherwise), and
        ``exec_tick_activity``'s nonzero cells are exactly the active cells
        — so ``ticks_active < ticks_total`` is real skipped work, not
        bookkeeping drift."""
        from repro.core.steptime import exec_tick_activity
        m = pp * groups if name == "interleaved" else 3 * groups
        v = v if name == "interleaved" else (2 if name == "dualpipe" else 1)
        sched = make_schedule(name, pp, m, n_chunks=v)
        tab = build_exec_tables(sched)
        per_rank = m * v if name == "interleaved" else m
        for r in range(pp):
            assert int((tab.f_act[:, r] > 0).sum()) == per_rank
            assert int((tab.b_act[:, r] > 0).sum()) == per_rank
            w = int((tab.w_act[:, r] > 0).sum())
            assert w == (m if name == "zb1p" else 0)
        acts = np.array(exec_tick_activity(name, pp, m, n_chunks=v))
        active = (tab.f_act > 0) | (tab.b_act > 0) | (tab.w_act > 0)
        assert np.array_equal(acts > 0, active)
        assert int(active.sum()) < acts.size   # idle cells exist at pp > 1
