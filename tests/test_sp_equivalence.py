"""Sequence-parallel executor equivalence: `make_pipeline_train_step(...,
sp=True)` — the Megatron ğ/dual boundary construction of `parallel/tp.py`
with seq-sharded residuals, boundary payloads and slot rings — reproduces
the sp=1 (single-device) step's loss and post-update master params to
bf16-accumulation tolerance.

Fast tier: one dense pp2×dp2×tp2×sp2 run with ZeRO-1 on, plus the loud
indivisible-seq guard, plus the overlap engine's SP-composed A/B check —
``gate_compute=False`` swaps every ``lax.cond`` for compute-both +
``jnp.where`` and must agree with the gated step bit-for-bit, proving the
gating changes cost, never SP numerics.  Slow tier: the full schedule × pp{1,2,4} × tp2 ×
sp grid (pp=1 only under 1f1b — interleaved/dualpipe require pp >= 2; the
sp=1 legs of the grid are exactly `tests/test_pipeline_3d.py` /
`test_pipeline_1f1b.py`, so only the sp=tp legs run here), the MoE/MLA
families (capacity_factor=4.0 so routing is dropless — per-shard capacity
C/sp vs global C drops different tokens near the capacity cliff, a real
behavioural difference of sharded routing, not an executor bug; params
match exactly either way), and the ZeRO-1-composes-with-SP invariant
(state arriving DP-sharded per `state_shardings`, the SP step still
matching, optimizer shards at 1/dp bytes).

Needs >1 fake device set before jax initialises — subprocess with XLA_FLAGS.
"""

import os
import subprocess
import sys
import textwrap

import pytest

HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    def check(tag, m1, s1, m2, s2, tol_loss=5e-3, tol_p=2e-2, tol_g=5e-2):
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < tol_loss, f"{tag}: loss diverged {dl}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < tol_p, f"{tag}: master params diverged {worst}"
        # grads must reproduce, not just the post-update params: one AdamW
        # step from zero moments is per-leaf scale-invariant
        # (m/(sqrt(v)+eps) cancels any scaling of g), so a tp x-wrong
        # gradient would still pass the master check.  After step 1,
        # m = (1-b1) g exactly — compare per-leaf *norms* (the tp=2 MLA
        # double-count this guards against showed ratios 0.5-2.0; the
        # sp=1 executor control sits at 1.00 +- 0.03, element-wise diffs
        # being bf16 accumulation noise shared with the TP-only path).
        worst_g = 0.0
        for a, b in zip(jax.tree.leaves(s1.m), jax.tree.leaves(s2.m)):
            n1 = float(jnp.linalg.norm(a.astype(jnp.float32)))
            n2 = float(jnp.linalg.norm(
                jax.device_get(b).astype(jnp.float32)))
            worst_g = max(worst_g, abs(n2 / max(n1, 1e-12) - 1.0))
        assert worst_g < tol_g, \
            f"{tag}: grad (first-moment) norms diverged {worst_g}"
        print(f"{tag}_OK", dl, worst, worst_g)
""")

DENSE_FAST = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                    zero=ZeROStage.OS, sp=True)
    s2, m2 = jax.jit(step)(state, batch)
    check("PP2_DP2_TP2_SP2_ZOS", m1, s1, m2, s2)

    # indivisible seq_len % sp raises loudly (no silent pad/replicate)
    bad = {k: v[:, :31] for k, v in batch.items()}
    try:
        jax.jit(step)(state, bad)
        raise SystemExit("indivisible seq was accepted")
    except ValueError as e:
        assert "sp=2" in str(e) and "s=31" in str(e), e
        print("SP_GUARD_OK")
""")

SP_GATE_AB = HEADER + textwrap.dedent("""
    import numpy as np
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    outs = {}
    for gate in (True, False):
        step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        zero=ZeROStage.OS, sp=True,
                                        gate_compute=gate)
        outs[gate] = jax.jit(step)(state, batch)
    (sg, mg), (su, mu) = outs[True], outs[False]
    assert float(mg["loss"]) == float(mu["loss"]), \
        (float(mg["loss"]), float(mu["loss"]))
    for a, b in zip(jax.tree.leaves(sg.master), jax.tree.leaves(su.master)):
        assert np.array_equal(jax.device_get(a), jax.device_get(b)), \
            "gated vs ungated SP master params differ bitwise"
    print("SP_GATE_AB_OK")
""")

DENSE_GRID_BODY = textwrap.dedent("""
    SCHEDULE = {schedule!r}
    N_CHUNKS = {n_chunks}
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    meshes = [(1, 2, 2), (2, 2, 2), (4, 1, 2)] if SCHEDULE == "1f1b" \\
        else [(2, 2, 2), (4, 1, 2)]
    for pp, data, tp in meshes:
        mesh = jax.make_mesh((pp, data, tp), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        schedule=SCHEDULE, n_chunks=N_CHUNKS,
                                        zero=ZeROStage.OS, sp=True)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"PP{{pp}}_DP{{data}}_TP{{tp}}_SP{{tp}}", m1, s1, m2, s2)
""")


def dense_grid_script(schedule, n_chunks):
    return HEADER + DENSE_GRID_BODY.format(schedule=schedule,
                                           n_chunks=n_chunks)


MOE_MLA_SP = HEADER + textwrap.dedent("""
    from repro.models.transformer import ModelOptions
    # olmoe: all-MoE softmax router (loss tol = the routing noise the sp=1
    # pipeline tests already grant it); deepseek: MLA latent towers
    # (gathered full-seq view, NO copy_to_tp on the latents — the entry
    # ğ's reduce-scatter backward does the cross-shard sum; the grad-norm
    # check below is what catches the tp× double-count if that ever
    # regresses) + mixed dense/MoE + sigmoid router + shared expert, with
    # seq-shard routing/dispatch.  capacity_factor=4.0 keeps both the
    # global and the per-shard routers dropless, so the SP step is
    # comparable to 5e-3 for deepseek (see module docstring).
    for name, layers, data, tol in [("olmoe-1b-7b", 4, 2, 1e-1),
                                    ("deepseek-v3", 4, 1, 5e-3)]:
        spec = dataclasses.replace(get_spec(name, smoke=True), n_layers=layers)
        model = build_model(spec, ModelOptions(capacity_factor=4.0))
        state = init_train_state(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(config_for(spec, 4, 32), 0)
        s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
        mesh = jax.make_mesh((2, data, 2), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=2), mesh,
                                        zero=ZeROStage.OS, sp=True)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"{name}_SP2", m1, s1, m2, s2, tol_loss=tol)
""")

ZERO_SP_INVARIANT = HEADER + textwrap.dedent("""
    from repro.parallel.sharding import state_shardings
    from repro.train.pipeline_loop import _EXEC_TP_RULES

    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    dp = mesh.shape["data"]

    def dev0_bytes(tree):
        return sum(x.addressable_shards[0].data.nbytes
                   for x in jax.tree.leaves(tree))

    # SP only re-shards activations: the ZeRO-1 state layout is untouched,
    # so a state arriving DP-sharded must run and reproduce the reference.
    sh_none = state_shardings(state, mesh, ZeROStage.NONE,
                              rules=_EXEC_TP_RULES)
    sh_os = state_shardings(state, mesh, ZeROStage.OS, rules=_EXEC_TP_RULES)
    st_os = jax.device_put(state, sh_os)
    for field in ("master", "m", "v"):
        ratio = dev0_bytes(getattr(st_os, field)) / dev0_bytes(
            jax.device_put(getattr(state, field), getattr(sh_none, field)))
        assert abs(ratio - 1.0 / dp) < 0.05, (field, ratio)
        print(f"{field}: per-device {ratio:.3f} of ZeRO-none (dp={dp})")

    step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                    zero=ZeROStage.OS, sp=True)
    s2, m2 = jax.jit(step)(st_os, batch)
    check("ZERO1_SP_COMPOSED", m1, s1, m2, s2)
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_sp_dense_fast():
    """pp2 × dp2 × tp2 × sp2 with ZeRO-1 + the indivisible-seq guard: the
    tier-1 SP smoke."""
    r = _run(DENSE_FAST)
    assert "PP2_DP2_TP2_SP2_ZOS_OK" in r.stdout and "SP_GUARD_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_sp_gate_compute_ab_bitwise():
    """Cond gating composes with SP: the gated (lax.cond) and ungated
    (compute-both + jnp.where) executors agree bit-for-bit on loss and
    post-update master params when the tick body carries SP's
    all-gather/reduce-scatter collectives inside the gated branches."""
    r = _run(SP_GATE_AB)
    assert "SP_GATE_AB_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
@pytest.mark.parametrize("schedule,n_chunks",
                         [("1f1b", 1), ("interleaved", 2), ("dualpipe", 2)])
def test_sp_grid(schedule, n_chunks):
    """schedule × pp{1,2,4} × tp2 × sp2 vs the single-device (sp=1) step."""
    r = _run(dense_grid_script(schedule, n_chunks))
    tags = ["PP2_DP2_TP2_SP2_OK", "PP4_DP1_TP2_SP2_OK"]
    if schedule == "1f1b":
        tags.append("PP1_DP2_TP2_SP2_OK")
    for tag in tags:
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_sp_moe_mla():
    r = _run(MOE_MLA_SP)
    assert "olmoe-1b-7b_SP2_OK" in r.stdout \
        and "deepseek-v3_SP2_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_zero1_composes_with_sp():
    """ZeRO-1 state sharded 1/dp per DP shard; the SP step consumes the
    sharded state and still reproduces the reference step."""
    r = _run(ZERO_SP_INVARIANT)
    assert "ZERO1_SP_COMPOSED_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
