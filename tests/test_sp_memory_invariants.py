"""SP invariants of the analytic activation model (hypothesis property
tests) + the sequence-divisibility guards.

The paper's Table 10 divides every sequence-resident tensor outside the
TP regions by sp and leaves the replicated MLA latents (2bs(d_cq+d_c))
and the MoE router activations (4bsN + 2bsN_r) undivided.  Now that the
executor makes sp real (`make_pipeline_train_step(..., sp=True)`), these
properties are the contract between the measured and analytic sides:

* activation bytes are monotone non-increasing in sp (over divisors of s);
* the sp=1 → sp delta is *exactly* the sum of the paper's /sp terms —
  nothing else moves;
* the MLA latent terms are invariant: scaling d_cq/d_c changes bytes but
  not the sp delta;
* indivisible ``s % sp`` warns loudly and falls back to SP-replicated
  accounting (mirroring `test_tp_guards.py`), is listed by
  ``tp_violations(..., sp=..., seq_len=...)``, and is rejected outright by
  the executor guard ``parallel.tp.check_sp_supported``.
"""

import dataclasses

import pytest

try:  # the property suite needs hypothesis (requirements-dev.txt); the
    # guard tests below run regardless — mirror test_tp_guards.py
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def _skip(*_a, **_k):
        return pytest.mark.skip(
            reason="property suite needs hypothesis (requirements-dev.txt)")

    given = settings = _skip

    class _Chain:
        def map(self, *_a, **_k):
            return self

    class st:  # noqa: N801 — stand-in so strategy expressions still parse
        @staticmethod
        def _chain(*_a, **_k):
            return _Chain()
        integers = sampled_from = tuples = _chain

from repro.configs import get_spec
from repro.core import ParallelConfig, RecomputePolicy, ZeROStage, estimate_memory
from repro.core.activations import (dense_mlp_activation_bytes,
                                    gqa_activation_bytes,
                                    mla_activation_bytes,
                                    moe_activation_bytes)
from repro.core.notation import tp_violations

QWEN = get_spec("qwen2-1.5b")
DS3 = get_spec("deepseek-v3")

SP_DEGREES = [1, 2, 4, 8, 16]


def sp_pairs():
    return st.tuples(st.sampled_from(SP_DEGREES),
                     st.sampled_from(SP_DEGREES)).map(sorted)


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 4), s16=st.integers(1, 256), pair=sp_pairs(),
       tp=st.sampled_from([1, 2]),
       rc=st.sampled_from(list(RecomputePolicy)))
def test_activation_bytes_monotone_in_sp(b, s16, pair, tp, rc):
    """Larger sp never costs more, for every family and recompute policy
    (s is a multiple of 16, so every drawn sp divides it)."""
    s = 16 * s16
    lo, hi = pair
    for fn, spec in ((mla_activation_bytes, DS3),
                     (gqa_activation_bytes, QWEN),
                     (dense_mlp_activation_bytes, QWEN)):
        assert fn(spec, b, s, tp=tp, sp=hi, cp=1, recompute=rc) \
            <= fn(spec, b, s, tp=tp, sp=lo, cp=1, recompute=rc)
    assert moe_activation_bytes(DS3, b, s, sp=hi, cp=1, ep=1, recompute=rc) \
        <= moe_activation_bytes(DS3, b, s, sp=lo, cp=1, ep=1, recompute=rc)


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 4), s16=st.integers(1, 256),
       sp=st.sampled_from(SP_DEGREES), tp=st.sampled_from([1, 2]))
def test_sp_delta_is_exactly_the_sequence_resident_terms(b, s16, sp, tp):
    """AC-None: sp=1 minus sp=k equals the shrink of exactly the paper's
    /sp terms — 5bsh for MLA (4bsh input + bsh output-grad buffer), 3bsh
    for GQA, 2bsh for dense MLP, 4bsh for MoE.  Everything else (TP-shared
    projections, s² scores, MLA latents, router activations, expert
    buffers) contributes zero to the delta."""
    s = 16 * s16
    rc = RecomputePolicy.NONE
    h = DS3.h
    d = mla_activation_bytes(DS3, b, s, tp=tp, sp=1, cp=1, recompute=rc) \
        - mla_activation_bytes(DS3, b, s, tp=tp, sp=sp, cp=1, recompute=rc)
    assert d == (4 * b * s * h - 4 * b * s * h // sp) \
        + (b * s * h - b * s * h // sp)

    h = QWEN.h
    d = gqa_activation_bytes(QWEN, b, s, tp=tp, sp=1, cp=1, recompute=rc) \
        - gqa_activation_bytes(QWEN, b, s, tp=tp, sp=sp, cp=1, recompute=rc)
    assert d == (2 * b * s * h - 2 * b * s * h // sp) \
        + (b * s * h - b * s * h // sp)

    d = dense_mlp_activation_bytes(QWEN, b, s, tp=tp, sp=1, cp=1,
                                   recompute=rc) \
        - dense_mlp_activation_bytes(QWEN, b, s, tp=tp, sp=sp, cp=1,
                                     recompute=rc)
    assert d == 2 * b * s * QWEN.h - 2 * b * s * QWEN.h // sp

    h = DS3.h
    d = moe_activation_bytes(DS3, b, s, sp=1, cp=1, ep=1, recompute=rc) \
        - moe_activation_bytes(DS3, b, s, sp=sp, cp=1, ep=1, recompute=rc)
    assert d == 4 * b * s * h - 4 * b * s * h // sp


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 4), s16=st.integers(1, 128),
       sp=st.sampled_from([2, 4, 8]), scale=st.sampled_from([2, 3, 4]))
def test_mla_latent_terms_sp_invariant(b, s16, sp, scale):
    """The replicated 2bs(d_cq+d_c) latents carry no /sp divisor: scaling
    the latent dims moves absolute bytes but not the sp delta."""
    s = 16 * s16
    big = dataclasses.replace(
        DS3, mla=dataclasses.replace(DS3.mla, d_cq=DS3.mla.d_cq * scale,
                                     d_c=DS3.mla.d_c * scale))
    kw = dict(tp=2, cp=1, recompute=RecomputePolicy.NONE)
    d_small = mla_activation_bytes(DS3, b, s, sp=1, **kw) \
        - mla_activation_bytes(DS3, b, s, sp=sp, **kw)
    d_big = mla_activation_bytes(big, b, s, sp=1, **kw) \
        - mla_activation_bytes(big, b, s, sp=sp, **kw)
    assert d_small == d_big
    assert mla_activation_bytes(big, b, s, sp=sp, **kw) \
        > mla_activation_bytes(DS3, b, s, sp=sp, **kw)


@settings(max_examples=30, deadline=None)
@given(tp=st.sampled_from([1, 2]), b=st.sampled_from([1, 2, 4]),
       z=st.sampled_from(list(ZeROStage)),
       rc=st.sampled_from(list(RecomputePolicy)))
def test_estimate_memory_sp_never_grows(tp, b, z, rc):
    """End-to-end: flipping the ParallelConfig sp knob on (degree = tp)
    never increases the activation estimate, and state bytes don't move
    (SP re-shards activations only)."""
    def cfg(sp):
        return ParallelConfig(dp=4, tp=tp, pp=2, ep=1, etp=1, sp=sp,
                              zero=z, recompute=rc, micro_batch=b,
                              seq_len=4096)
    on = estimate_memory(DS3, cfg(True), stage=0)
    off = estimate_memory(DS3, cfg(False), stage=0)
    assert on.activations <= off.activations
    if tp > 1 and rc != RecomputePolicy.FULL:
        assert on.activations < off.activations
    assert (on.params, on.grads, on.optimizer) \
        == (off.params, off.grads, off.optimizer)


def test_indivisible_sp_warns_and_falls_back():
    """s % sp != 0 used to floor-divide silently (under-counting); now it
    warns and models the tensor as SP-replicated — the same loud-fallback
    contract as the TP guards."""
    b, s = 2, 1023
    with pytest.warns(RuntimeWarning, match="sp=2 does not divide"):
        got = gqa_activation_bytes(QWEN, b, s, tp=1, sp=2, cp=1,
                                   recompute=RecomputePolicy.NONE)
    assert got == gqa_activation_bytes(QWEN, b, s, tp=1, sp=1, cp=1,
                                       recompute=RecomputePolicy.NONE)
    with pytest.warns(RuntimeWarning, match="sp=2"):
        full = mla_activation_bytes(DS3, b, s, tp=1, sp=2, cp=1,
                                    recompute=RecomputePolicy.FULL)
    assert full == 2 * b * s * DS3.h


def test_sp_violations_listed_and_executor_rejects():
    """tp_violations grows the sp/seq_len axis; the executor's hard guard
    (parallel.tp.check_sp_supported) raises on it, and the planner marks
    such configs not runnable."""
    from repro.core import executor_runnable
    assert tp_violations(QWEN, 2, sp=2, seq_len=4096) == []
    bad = tp_violations(QWEN, 2, sp=2, seq_len=4097)
    assert any("s=4097" in x for x in bad)
    # sp violation is reported even at tp degrees that divide everything
    assert tp_violations(QWEN, 1, sp=2, seq_len=4097)

    tp_mod = pytest.importorskip("repro.parallel.tp")
    with pytest.raises(ValueError, match="s=4097"):
        tp_mod.check_sp_supported(QWEN, 2, 4097)
    with pytest.raises(ValueError, match="ties its degree"):
        tp_mod.check_sp_supported(QWEN, 1, 4096)

    cfg = ParallelConfig(dp=4, tp=2, pp=1, sp=True, seq_len=4097)
    ok, why = executor_runnable(QWEN, cfg)
    assert not ok and "s=4097" in why
    ok, why = executor_runnable(
        QWEN, dataclasses.replace(cfg, seq_len=4096))
    assert ok, why
