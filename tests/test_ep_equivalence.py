"""Expert-parallel executor equivalence + analytic EP invariants.

`make_pipeline_train_step(..., ep=tp)` — expert-dim weight shards with
all-to-all token dispatch over 'model' (`models.moe._moe_forward_ep`) —
must reproduce the ep=1 step's loss / master params / first-moment norms
to bf16-accumulation tolerance, capacity-matched (capacity_factor=4.0
keeps both the global and the per-chunk routers dropless; near the
capacity cliff the two drop different tokens, a real behavioural
difference of sharded routing, not an executor bug).

Fast tier: one olmoe pp2×dp2×tp2×ep2 run with ZeRO-1 on plus the loud
EP guards, and a functional check of the a2a dispatch against the
dropless dense reference on a bare 'model' mesh.  Slow tier: the
schedule × pp{1,2} × tp2 × ep2 × sp{off,on} grid and the deepseek-v3
leg (MLA + shared expert + mixed dense/MoE + sigmoid router).

Also here (no subprocess): hypothesis invariants of the analytic MoE
activation model in ep — monotone non-increasing, with the ep delta
equal to *exactly* the `(E/ep, C, h)` dispatch-buffer terms — and the
planner/guard contract for EP configs.

Needs >1 fake device set before jax initialises — subprocess with
XLA_FLAGS (mirrors tests/test_sp_equivalence.py).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import pytest

try:  # property suite needs hypothesis; everything else runs regardless
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def _skip(*_a, **_k):
        return pytest.mark.skip(
            reason="property suite needs hypothesis (requirements-dev.txt)")

    given = settings = _skip

    class st:  # noqa: N801 — stand-in so strategy expressions still parse
        @staticmethod
        def _chain(*_a, **_k):
            return None
        integers = sampled_from = _chain

from repro.configs import get_spec
from repro.core import ParallelConfig, RecomputePolicy, executor_runnable
from repro.core.activations import moe_activation_bytes
from repro.core.notation import tp_violations

DS3 = get_spec("deepseek-v3")
OLMOE = get_spec("olmoe-1b-7b")
QWEN_MOE = get_spec("qwen2-moe-a2.7b")

HEADER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.models.transformer import ModelOptions
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    def check(tag, m1, s1, m2, s2, tol_loss=5e-3, tol_p=2e-2, tol_g=5e-2):
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < tol_loss, f"{tag}: loss diverged {dl}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < tol_p, f"{tag}: master params diverged {worst}"
        # grads must reproduce, not just the post-update params: one AdamW
        # step from zero moments is per-leaf scale-invariant, so compare
        # the first moments m = (1-b1) g by norm (the check that catches a
        # missing — or double — router psum, which shows ratios 0.5-2.0)
        worst_g = 0.0
        for a, b in zip(jax.tree.leaves(s1.m), jax.tree.leaves(s2.m)):
            n1 = float(jnp.linalg.norm(a.astype(jnp.float32)))
            n2 = float(jnp.linalg.norm(
                jax.device_get(b).astype(jnp.float32)))
            worst_g = max(worst_g, abs(n2 / max(n1, 1e-12) - 1.0))
        assert worst_g < tol_g, \
            f"{tag}: grad (first-moment) norms diverged {worst_g}"
        print(f"{tag}_OK", dl, worst, worst_g)
""")

FAST = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("olmoe-1b-7b", smoke=True),
                               n_layers=4)
    model = build_model(spec, ModelOptions(capacity_factor=4.0))
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                    zero=ZeROStage.OS, ep=2)
    s2, m2 = jax.jit(step)(state, batch)
    check("PP2_DP2_TP2_EP2_ZOS", m1, s1, m2, s2, tol_loss=1e-1)

    # the a2a dispatch group is the whole 'model' axis: ep must equal tp
    try:
        make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh, ep=4)
        raise SystemExit("ep != tp was accepted")
    except ValueError as e:
        assert "ep == tp" in str(e) or "a2a" in str(e), e
        print("EP_TIE_GUARD_OK")
    # and a dense model has no experts to parallelise
    dense = build_model(get_spec("qwen2-1.5b", smoke=True))
    try:
        make_pipeline_train_step(dense, TrainConfig(n_micro=4), mesh, ep=2)
        raise SystemExit("dense + ep was accepted")
    except ValueError as e:
        assert "MoE" in str(e), e
        print("EP_MOE_GUARD_OK")
""")

DENSE_REF = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_spec
    from repro.models.moe import moe_forward, moe_forward_dense_ref, moe_init
    from repro.parallel.compat import shard_map

    spec = get_spec("olmoe-1b-7b", smoke=True)      # 4 experts top-2
    mesh = jax.make_mesh((2,), ("model",))
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32),
                       moe_init(jax.random.PRNGKey(0), spec))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, spec.h), jnp.float32)
    cap = float(spec.moe.n_routed) * 4              # dropless

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=({"router": P(None, None),
                   "we_gate": P("model", None, None),
                   "we_up": P("model", None, None),
                   "we_down": P("model", None, None)}, P()),
        out_specs=(P(), P()))
    def ep_body(lp, xs):
        out = moe_forward(lp, spec, xs, capacity_factor=cap,
                          ep=2, ep_axis="model")
        return out.y, out.aux_loss

    with mesh:
        y_ep, aux_ep = jax.jit(ep_body)(p32, x)
    ref = moe_forward(p32, spec, x, capacity_factor=cap)
    dense = moe_forward_dense_ref(p32, spec, x)
    err_d = float(jnp.abs(y_ep - dense).max())
    err_s = float(jnp.abs(y_ep - ref.y).max())
    err_a = abs(float(aux_ep) - float(ref.aux_loss))
    assert err_d < 2e-3, f"EP vs dense-ref max err {err_d}"
    assert err_s < 2e-3, f"EP vs scatter max err {err_s}"
    assert err_a < 1e-5, f"EP aux vs scatter {err_a}"

    # gradients flow through both all_to_alls and the token-slice boundary
    with mesh:
        g = jax.jit(jax.grad(lambda x_: ep_body(p32, x_)[0].sum()))(x)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
    print("EP_DENSE_REF_OK", err_d, err_s, err_a)
""")

GRID_BODY = textwrap.dedent("""
    SCHEDULE = {schedule!r}
    N_CHUNKS = {n_chunks}
    spec = dataclasses.replace(get_spec("olmoe-1b-7b", smoke=True),
                               n_layers=8)
    model = build_model(spec, ModelOptions(capacity_factor=4.0))
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    meshes = [(1, 2, 2), (2, 2, 2)] if SCHEDULE == "1f1b" else [(2, 2, 2)]
    for pp, data, tp in meshes:
        mesh = jax.make_mesh((pp, data, tp), ("pipe", "data", "model"))
        for sp in (False, True):
            step = make_pipeline_train_step(
                model, TrainConfig(n_micro=4), mesh, schedule=SCHEDULE,
                n_chunks=N_CHUNKS, zero=ZeROStage.OS, sp=sp, ep=tp)
            s2, m2 = jax.jit(step)(state, batch)
            check(f"PP{{pp}}_TP{{tp}}_EP{{tp}}_SP{{int(sp)}}", m1, s1, m2, s2,
                  tol_loss=1e-1)
""")

MOE_MLA_EP = HEADER + textwrap.dedent("""
    # deepseek-v3: MLA latent towers + mixed dense/MoE layers + sigmoid
    # router + a shared expert (which must stay on the ETP f/g path while
    # the routed experts dispatch over the a2a)
    spec = dataclasses.replace(get_spec("deepseek-v3", smoke=True),
                               n_layers=4)
    model = build_model(spec, ModelOptions(capacity_factor=4.0))
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 4, 32), 0)
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
    for sp in (False, True):
        mesh = jax.make_mesh((2, 1, 2), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=2), mesh,
                                        zero=ZeROStage.OS, sp=sp, ep=2)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"DSV3_EP2_SP{int(sp)}", m1, s1, m2, s2)
""")


def grid_script(schedule, n_chunks):
    return HEADER + GRID_BODY.format(schedule=schedule, n_chunks=n_chunks)


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_ep_fast():
    """pp2 × dp2 × tp2 × ep2 with ZeRO-1 + the loud EP guards: the tier-1
    EP smoke."""
    r = _run(FAST)
    for tag in ("PP2_DP2_TP2_EP2_ZOS_OK", "EP_TIE_GUARD_OK",
                "EP_MOE_GUARD_OK"):
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_ep_dispatch_matches_dense_ref():
    """The a2a dispatch (shard-mapped over a bare 'model' mesh) equals the
    dropless dense reference AND the scatter path — output, aux and
    gradient flow — at matched capacity."""
    r = _run(DENSE_REF)
    assert "EP_DENSE_REF_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
@pytest.mark.parametrize("schedule,n_chunks",
                         [("1f1b", 1), ("interleaved", 2), ("dualpipe", 2)])
def test_ep_grid(schedule, n_chunks):
    """schedule × pp{1,2} × tp2 × ep2 × sp{off,on} vs the single-device
    (ep=1) step."""
    r = _run(grid_script(schedule, n_chunks))
    tags = ["PP2_TP2_EP2_SP0_OK", "PP2_TP2_EP2_SP1_OK"]
    if schedule == "1f1b":
        tags += ["PP1_TP2_EP2_SP0_OK", "PP1_TP2_EP2_SP1_OK"]
    for tag in tags:
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_ep_moe_mla():
    r = _run(MOE_MLA_EP)
    assert "DSV3_EP2_SP0_OK" in r.stdout and "DSV3_EP2_SP1_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


# ---------------------------------------------------------------------------
# Analytic invariants (no subprocess): the (E/ep, C, h) dispatch terms
# ---------------------------------------------------------------------------

def _dispatch_terms(spec, b, s, ep, rc):
    """The routed-expert buffer bytes the model books at EP degree ``ep`` —
    the same int() placement as ``moe_activation_bytes``."""
    e = spec.moe
    n_local = e.n_routed // ep
    e_token = b * s * e.n_active / e.n_routed
    if rc == RecomputePolicy.SELECTIVE:
        return int(n_local * 2 * e_token * spec.h)
    return int(n_local * (3 * e_token * spec.h + 8 * e_token * e.d_ff_expert))


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 4), s16=st.integers(1, 256),
       lo=st.sampled_from([1, 2, 4]), hi=st.sampled_from([4, 8, 16]),
       rc=st.sampled_from(list(RecomputePolicy)))
def test_moe_bytes_monotone_in_ep(b, s16, lo, hi, rc):
    """Larger ep never costs more, for every MoE family and recompute
    policy (every drawn degree divides both n_routed counts: 256 and 64)."""
    s = 16 * s16
    for spec in (DS3, OLMOE):
        assert moe_activation_bytes(spec, b, s, sp=1, cp=1, ep=hi,
                                    recompute=rc) \
            <= moe_activation_bytes(spec, b, s, sp=1, cp=1, ep=lo,
                                    recompute=rc)


@settings(max_examples=60, deadline=None)
@given(b=st.integers(1, 4), s16=st.integers(1, 256),
       ep=st.sampled_from([2, 4, 8, 16]),
       rc=st.sampled_from(list(RecomputePolicy)))
def test_ep_delta_is_exactly_the_dispatch_terms(b, s16, ep, rc):
    """ep=1 minus ep=k equals the shrink of *exactly* the dispatch-buffer
    terms — n_local·(3 E_token h + 8 E_token h_E) for AC-None, the kept
    n_local·2 E_token h for AC-Selective, nothing for AC-Full (only block
    inputs + router outputs are stored).  Router activations (4bsN +
    2bsN_r), residual terms and the shared expert contribute zero."""
    s = 16 * s16
    for spec in (DS3, OLMOE):
        d = moe_activation_bytes(spec, b, s, sp=1, cp=1, ep=1, recompute=rc) \
            - moe_activation_bytes(spec, b, s, sp=1, cp=1, ep=ep,
                                   recompute=rc)
        if rc == RecomputePolicy.FULL:
            assert d == 0
        else:
            assert d == _dispatch_terms(spec, b, s, 1, rc) \
                - _dispatch_terms(spec, b, s, ep, rc)


def test_indivisible_ep_warns_and_falls_back():
    """ep ∤ n_routed warns and models the buffer as EP-replicated (the
    loud-fallback contract shared with the TP/SP guards)."""
    with pytest.warns(RuntimeWarning, match="n_routed"):
        got = moe_activation_bytes(OLMOE, 2, 64, sp=1, cp=1, ep=3,
                                   recompute=RecomputePolicy.NONE)
    assert got == moe_activation_bytes(OLMOE, 2, 64, sp=1, cp=1, ep=1,
                                       recompute=RecomputePolicy.NONE)


def test_ep_violations_listed_and_executor_guards():
    """tp_violations grows the ep axis; check_ep_supported raises on the
    untieable degrees; executor_runnable marks MoE+EP configs runnable
    exactly when the executor can place them (ep == tp, divisible)."""
    assert tp_violations(OLMOE, 2, ep=2) == []
    assert any("n_routed=60" in v for v in tp_violations(QWEN_MOE, 2, ep=8))

    tp_mod = pytest.importorskip("repro.parallel.tp")
    tp_mod.check_ep_supported(OLMOE, 2, 2)                 # ok
    tp_mod.check_ep_supported(OLMOE, 2, 1)                 # ETP path, ok
    with pytest.raises(ValueError, match="tied to it"):
        tp_mod.check_ep_supported(OLMOE, 4, 2)
    with pytest.raises(ValueError, match="MoE"):
        tp_mod.check_ep_supported(get_spec("qwen2-1.5b"), 2, 2)
    with pytest.raises(ValueError, match="n_routed"):
        tp_mod.check_ep_supported(QWEN_MOE, 8, 8)
    with pytest.raises(ValueError, match="token count"):
        tp_mod.check_ep_supported(OLMOE, 2, 2, tokens_per_rank=33)

    # planner: the old flat "EP is dry-run-only" rejection is gone —
    # executor-placeable EP configs rank as runnable, the wider enumeration
    # space stays estimator-only with the reason recorded
    ok, why = executor_runnable(
        OLMOE, ParallelConfig(dp=4, tp=2, ep=2, sp=True))
    assert ok, why
    ok, why = executor_runnable(
        OLMOE, ParallelConfig(dp=4, tp=4, ep=2, sp=True))
    assert not ok and "estimator-only" in why
    ok, why = executor_runnable(
        QWEN_MOE, ParallelConfig(dp=8, tp=2, ep=8, sp=True))
    assert not ok and "n_routed" in why


def test_planner_surfaces_runnable_ep():
    """plan() over a small world produces at least one runnable EP>1 entry
    for an MoE model (the acceptance criterion's 'no longer rejecting'),
    and the estimator-only grouped-EP configs carry a precise reason.
    Runnable configs rank first (by predicted step time), so the
    estimator-only entries live past the runnable block — probe with an
    uncapped top_k."""
    from repro.core.planner import plan
    entries = plan(OLMOE, 16, 96 * 2 ** 30, seq_len=4096, top_k=50)
    assert any(e.cfg.ep > 1 and e.runnable for e in entries), \
        [(e.cfg.describe(), e.why_not_runnable) for e in entries[:10]]
    full = plan(OLMOE, 16, 96 * 2 ** 30, seq_len=4096, top_k=10 ** 6)
    kinds = {e.why_not_runnable for e in full
             if e.cfg.ep > 1 and not e.runnable}
    assert any("estimator-only" in w for w in kinds)
