"""Training-loop semantics: gradient accumulation equivalence, fp32
buffers (paper Table 7), state dtype layout, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.data.synthetic import config_for, make_batch
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, TrainState, init_train_state
from repro.train.loop import TrainConfig, make_train_step

SPEC = get_spec("minitron-4b", smoke=True)


def _setup():
    model = build_model(SPEC)
    params = model.init(jax.random.PRNGKey(0))
    return model, init_train_state(params)


def test_state_dtypes_match_table7():
    _, state = _setup()
    for p in jax.tree.leaves(state.params):
        assert p.dtype == jnp.bfloat16           # weights 2B
    for m in jax.tree.leaves(state.master):
        assert m.dtype == jnp.float32            # fp32 copy 4B
    for m in jax.tree.leaves(state.m):
        assert m.dtype == jnp.bfloat16           # momentum 2B
    for v in jax.tree.leaves(state.v):
        assert v.dtype == jnp.bfloat16           # variance 2B


def test_grad_accumulation_equivalence():
    """n_micro=2 over a batch == n_micro=1 over the same batch (mean of
    micro-grads == full-batch grad for a mean loss), up to bf16 noise."""
    model, state = _setup()
    batch = make_batch(config_for(SPEC, 4, 32), 0)
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=1)))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    # parameters after one update should be near-identical
    for a, b in zip(jax.tree.leaves(s1.master), jax.tree.leaves(s2.master)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-2, rtol=2e-2)


def test_master_params_stay_synced():
    model, state = _setup()
    batch = make_batch(config_for(SPEC, 2, 16), 0)
    step = jax.jit(make_train_step(model, TrainConfig()))
    for i in range(3):
        state, _ = step(state, batch)
    for p, m in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(state.master)):
        np.testing.assert_array_equal(
            np.asarray(p, np.float32),
            np.asarray(m.astype(jnp.bfloat16), np.float32))


def test_grad_clip_engages():
    from repro.optim.adamw import adamw_update
    _, state = _setup()
    huge = jax.tree.map(lambda p: jnp.full(p.shape, 1e6, jnp.float32),
                        state.params)
    new_state, metrics = jax.jit(
        lambda s, g: adamw_update(s, g, AdamWConfig(grad_clip=1.0)))(state, huge)
    assert float(metrics["grad_norm"]) > 1e6
    # post-clip update magnitude bounded by lr * O(1)
    for a, b in zip(jax.tree.leaves(new_state.master),
                    jax.tree.leaves(state.master)):
        assert float(jnp.abs(a - b).max()) < 0.1


def test_deterministic_steps():
    model, state = _setup()
    batch = make_batch(config_for(SPEC, 2, 16), 0)
    step = jax.jit(make_train_step(model, TrainConfig()))
    s1, _ = step(state, batch)
    s2, _ = step(state, batch)
    for a, b in zip(jax.tree.leaves(s1.master), jax.tree.leaves(s2.master)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
