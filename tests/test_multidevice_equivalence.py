"""Distribution must not change numerics: the same train step on a 1-device
mesh and a (2,4) mesh with ZeRO-3 sharding produces the same loss and
updated master params (up to collective reduction reassociation).

Runs in a subprocess (needs 8 fake devices before jax init)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_spec
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.launch.specs import batch_shardings
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.parallel.axes import axis_rules
    from repro.parallel.sharding import state_shardings
    from repro.train.loop import TrainConfig, make_train_step

    spec = get_spec("olmoe-1b-7b", smoke=True)   # MoE: exercises EP sharding
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    batch = make_batch(config_for(spec, 4, 32), 0)
    step = make_train_step(model, TrainConfig(n_micro=2))

    # single device
    s1, m1 = jax.jit(step)(state, batch)

    # 2x4 mesh, ZeRO os+g+params
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    abstract = jax.eval_shape(lambda: state)
    st_sh = state_shardings(abstract, mesh, ZeROStage.OS_G_PARAMS)
    b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
    with axis_rules(mesh):
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
        s2, m2 = fn(jax.device_put(state, st_sh), jax.device_put(batch, b_sh))

    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    assert dl < 5e-2, f"loss diverged: {dl}"
    worst = 0.0
    for a, b in zip(jax.tree.leaves(s1.master), jax.tree.leaves(s2.master)):
        worst = max(worst, float(jnp.abs(a - jax.device_get(b)).max()))
    assert worst < 5e-2, f"master params diverged: {worst}"
    print("MULTIDEV_OK", dl, worst)
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEV_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
