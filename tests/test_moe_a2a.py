"""a2a (shard_map all-to-all) MoE vs the GSPMD scatter path: numerical
equivalence on a small multi-device mesh.

Needs >1 fake device, which must be set before jax initialises — so the
mesh-dependent checks run in a subprocess with XLA_FLAGS set.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_spec
    from repro.models.moe import moe_forward, moe_init
    from repro.models.moe_a2a import moe_forward_a2a

    spec = get_spec("olmoe-1b-7b", smoke=True)   # 4 experts top-2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = moe_init(jax.random.PRNGKey(0), spec)
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, spec.h), jnp.float32)

    # capacity high enough that neither path drops tokens
    cap = float(spec.moe.n_routed) * 4
    with mesh:
        a2a = jax.jit(lambda p_, x_: moe_forward_a2a(
            p_, spec, x_, mesh=mesh, capacity_factor=cap).y)(p32, x)
    ref = moe_forward(p32, spec, x, capacity_factor=cap).y
    err = float(jnp.abs(a2a - ref).max())
    assert err < 2e-3, f"a2a vs scatter max err {err}"

    # gradients flow through the exchange
    with mesh:
        g = jax.jit(jax.grad(lambda x_: moe_forward_a2a(
            p32, spec, x_, mesh=mesh, capacity_factor=cap).y.sum()))(x)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
    print("A2A_OK", err)
""")


@pytest.mark.slow
def test_a2a_matches_scatter_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "A2A_OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
