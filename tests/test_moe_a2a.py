"""a2a (shard_map all-to-all) MoE vs the GSPMD scatter path: numerical
equivalence on a small multi-device mesh.

Needs >1 fake device, which must be set before jax initialises — so the
mesh-dependent checks run in a subprocess with XLA_FLAGS set.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_spec
    from repro.models.moe import moe_forward, moe_init
    from repro.models.moe_a2a import moe_forward_a2a

    spec = get_spec("olmoe-1b-7b", smoke=True)   # 4 experts top-2
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = moe_init(jax.random.PRNGKey(0), spec)
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, spec.h), jnp.float32)

    # capacity high enough that neither path drops tokens
    cap = float(spec.moe.n_routed) * 4
    with mesh:
        out = jax.jit(lambda p_, x_: moe_forward_a2a(
            p_, spec, x_, mesh=mesh, capacity_factor=cap))(p32, x)
    ref = moe_forward(p32, spec, x, capacity_factor=cap)
    err = float(jnp.abs(out.y - ref.y).max())
    assert err < 2e-3, f"a2a vs scatter max err {err}"

    # router_probs regression: the zeros stub is gone — a2a returns the
    # assembled global (T, E) probs, identical to the scatter path's
    # (routing is per-token, so sharding cannot change it)
    assert out.router_probs.shape == ref.router_probs.shape, \
        (out.router_probs.shape, ref.router_probs.shape)
    perr = float(jnp.abs(out.router_probs - ref.router_probs).max())
    assert perr < 1e-5, f"a2a router_probs diverged {perr}"
    assert float(jnp.abs(out.router_probs).max()) > 0

    # gradients flow through the exchange
    with mesh:
        g = jax.jit(jax.grad(lambda x_: moe_forward_a2a(
            p32, spec, x_, mesh=mesh, capacity_factor=cap).y.sum()))(x)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
    print("A2A_OK", err, perr)
""")


@pytest.mark.slow
def test_a2a_matches_scatter_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "A2A_OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_local_capacity_applies_factor_once():
    """Regression for the double-applied capacity_factor: ``c_send``
    already includes it, and ``c_loc`` was derived from ``M·c_send`` and
    multiplied by it AGAIN (~cf× oversized local buffer).  The local
    (E_loc, C, h) capacity must match the estimator's per-expert term:
    C = E_token·cf = tk/E_loc·cf — the same C the ep=1 scatter path books
    (``moe_forward``'s  round(T·K/E·cf)  with T·K = tk·M, E = E_loc·M)."""
    from repro.models.moe_a2a import local_expert_capacity

    for tk, e_loc, cf in [(64, 1, 1.25), (64, 2, 1.25), (256, 8, 1.0),
                          (1024, 16, 1.25), (100, 3, 2.0)]:
        got = local_expert_capacity(tk, e_loc, cf)
        assert got == max(1, round(tk / e_loc * cf)), (tk, e_loc, cf, got)
        # the old formula: round(M*c_send/E_loc * cf) with c_send already
        # cf-scaled — strictly larger whenever cf > 1
        for m in (2, 4):
            c_send = max(1, round(tk / m * cf))
            old = max(1, round(m * c_send / e_loc * cf))
            if cf > 1 and tk / e_loc * cf > 4:
                assert got < old, (tk, e_loc, cf, m, got, old)


def test_local_capacity_matches_estimator_dispatch_row():
    """The buffer the a2a path allocates is byte-for-byte the estimator's
    ``(E/ep, C, h)`` dispatch term: n_local·C·h at the activation width
    equals the E_token-based routed buffer row of
    ``core.activations.moe_activation_bytes`` (cf=1 ⇒ C == E_token)."""
    from repro.configs import get_spec
    from repro.models.moe_a2a import local_expert_capacity

    spec = get_spec("olmoe-1b-7b")
    e = spec.moe
    b, s, M = 2, 4096, 8           # 8-way model axis, tokens seq-sharded
    t_loc = b * s // M
    tk = t_loc * e.n_active
    e_loc = e.n_routed // M
    c = local_expert_capacity(tk, e_loc, 1.0)
    e_token_global = b * s * e.n_active / e.n_routed
    assert c == round(e_token_global), (c, e_token_global)
