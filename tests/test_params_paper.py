"""Exactness tests: reproduce the paper's Tables 3, 4, 6 to the parameter."""

import pytest

from repro.configs import get_spec
from repro.core import params as P
from repro.core.parallel_config import PAPER_CONFIG

SPEC = get_spec("deepseek-v3")


def test_embedding_params():
    assert SPEC.embedding_params() == 926_679_040


def test_mla_params_paper_row():
    # Table 3 MLA row (includes q/kv RMSNorm weights)
    assert P.mla_params_paper(SPEC) == 187_107_328
    # projection-only count (the de-duplicated truth)
    assert SPEC.attn_params_per_layer(include_qk_norm=False) == 187_105_280


def test_dense_mlp_params():
    assert SPEC.dense_mlp_params_per_layer() == 3 * 7168 * 18432 == 396_361_728


def test_ln_row():
    assert P.ln_params_paper(SPEC) == 2 * 7168 + 1536 + 512 == 16_384


def test_gate_and_experts():
    assert SPEC.moe.n_routed * SPEC.h == 1_835_008
    experts = 3 * SPEC.h * SPEC.moe.d_ff_expert * (SPEC.moe.n_routed + SPEC.moe.n_shared)
    assert experts == 11_318_329_344


def test_table3_group_totals():
    rows = P.table3_rows(SPEC)
    per_layer = {r.layers: r.per_layer for r in rows}
    assert per_layer["Layer 0"] == 1_510_164_480            # ~1.5 B
    assert per_layer["Layers 1 - 2"] == 583_485_440          # ~0.58 B
    assert per_layer["Layers 3 - 59"] == 11_507_288_064      # ~11.5 B
    assert per_layer["Layer 60"] == 12_433_967_104           # ~12.4 B


def test_total_params_671b():
    total = P.total_params_paper(SPEC)
    assert total == 671_026_522_112
    assert round(total / 1e9) == 671


def test_table4_pp16_stages():
    rows = P.table4_stages(SPEC, pp=16)
    assert len(rows) == 16
    assert [len(r.layers) for r in rows] == [4] * 15 + [1]
    # Stage 0: layers 0-3 (~14.16B per paper's rounding)
    assert rows[0].params == (1_510_164_480 + 2 * 583_485_440 + 11_507_288_064)
    # Stages 1-14: identical, 4 MoE layers each = 46 B
    for r in rows[1:15]:
        assert r.params == 4 * 11_507_288_064 == 46_029_152_256
    # Stage 15: layer 60 = 12.4 B
    assert rows[15].params == 12_433_967_104
    assert sum(r.params for r in rows) == P.total_params_paper(SPEC)


def test_table6_device_params():
    dev = P.device_params(SPEC, PAPER_CONFIG)
    assert dev.norms == 65_536
    assert dev.attn_tp == 318_767_104
    assert dev.attn_replicated == 110_886_912
    assert dev.attn_tp + dev.attn_replicated == 429_654_016          # MLA row
    assert dev.non_expert == 429_719_552                              # non-MoE part
    assert dev.router == 4 * 1_835_008
    assert dev.experts == 5_813_305_344
    assert dev.expert == 5_820_645_376                                # MoE row
    assert dev.total == 6_250_364_928                                 # Table 6 total
    assert dev.total * 2 == 12_500_729_856                            # bytes


def test_stage_selection_matches_paper_interior_stage():
    # §3 analyses stages 1-14 (4 MoE layers, no embedding); the default
    # stage=None must pick such a stage.
    dev_default = P.device_params(SPEC, PAPER_CONFIG)
    dev_stage1 = P.device_params(SPEC, PAPER_CONFIG, stage=1)
    assert dev_default == dev_stage1
