"""MoE correctness: capacity dispatch vs the dropless dense reference,
router invariants, and capacity-drop behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.models.moe import (_positions_in_expert, _send_eid_buffer,
                              moe_forward, moe_forward_dense_ref, moe_init)

SPEC = get_spec("olmoe-1b-7b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return moe_init(jax.random.PRNGKey(0), SPEC)


@pytest.mark.parametrize("router", ["softmax", "sigmoid"])
def test_capacity_dispatch_matches_dense_ref(params, router):
    """With capacity high enough that nothing drops, the sort/scatter
    dispatch must equal the dense dropless reference (fp32: the two paths
    round differently in bf16 — dispatch rounds per expert-output, the ref
    rounds once after the combine einsum)."""
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, SPEC.h),
                          jnp.float32)
    got = moe_forward(p32, SPEC, x, capacity_factor=float(SPEC.moe.n_routed),
                      router_impl=router).y
    want = moe_forward_dense_ref(p32, SPEC, x, router_impl=router)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_positions_in_expert():
    eids = jnp.asarray([2, 0, 2, 1, 0, 2, 2], jnp.int32)
    pos, counts = _positions_in_expert(eids, 4)
    assert counts.tolist() == [2, 1, 4, 0]
    # ranks within each expert, in original order
    assert pos.tolist() == [0, 0, 1, 0, 1, 2, 3]


def test_positions_in_expert_property():
    rng = np.random.default_rng(0)
    for _ in range(10):
        E = int(rng.integers(2, 9))
        eids = jnp.asarray(rng.integers(0, E, size=64), jnp.int32)
        pos, counts = _positions_in_expert(eids, E)
        pos = np.asarray(pos)
        for e in range(E):
            mine = pos[np.asarray(eids) == e]
            assert sorted(mine.tolist()) == list(range(len(mine)))
        assert int(counts.sum()) == 64


def test_send_eid_buffer_drops_overflow_writes():
    """Regression: on destination-bucket overflow, the dropped assignment's
    (clamped) padding write used to collide with slot c_send-1's real
    expert-id write — scatter-set keeps an arbitrary duplicate, so a kept
    token's expert output could be silently zeroed.  Unclamped positions
    with mode="drop" never write out-of-capacity entries."""
    dest = jnp.asarray([0, 0, 0, 1], jnp.int32)
    pos = jnp.asarray([0, 1, 2, 0], jnp.int32)   # dest 0 overflows cap 2
    eid = jnp.asarray([3, 1, 2, 0], jnp.int32)
    buf = _send_eid_buffer(dest, pos, eid, 2, 2, 4)
    # slot (0,1) keeps expert id 1; the overflow (pos=2) is dropped, and
    # the unwritten slot (1,1) carries the padding marker e_loc=4
    assert buf.tolist() == [[3, 1], [0, 4]]


def test_capacity_drops_tokens_but_stays_finite(params):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, SPEC.h),
                          jnp.float32).astype(jnp.bfloat16)
    out = moe_forward(params, SPEC, x, capacity_factor=0.25)
    assert jnp.isfinite(out.y.astype(jnp.float32)).all()
    # dropped tokens => output can differ from dropless, but shapes hold
    assert out.y.shape == x.shape


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux ≈ 1 (Switch normalisation)."""
    import dataclasses
    p = moe_init(jax.random.PRNGKey(3), SPEC)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, SPEC.h),
                          jnp.float32).astype(jnp.bfloat16)
    out = moe_forward(p, SPEC, x)
    # ties in top_k make f_e uniform-ish; P_e exactly uniform
    assert 0.9 < float(out.aux_loss) < 1.3
