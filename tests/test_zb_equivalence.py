"""ZB-H1 executor equivalence: ``make_pipeline_train_step(...,
schedule="zb1p")`` reproduces the pp=1 single-device step to
bf16-accumulation tolerance.

The zb1p executor runs the real ZB-H1 split: the B tick runs the full
chunk vjp once (no recompute replay) and parks the fp32 pending-dW in the
scan-carried stash ring; the dedicated W tick flushes that stash slot into
the grad accumulator — so the post-step master params, loss and
first-moment norms must match the reference exactly as tightly as the
1f1b path does (``check()``'s 5e-3 / 2e-2 / 5e-2 bands, shared with
``test_sp_equivalence.py``).  Shared embed/head/final-norm grads
accumulate at B (they never enter the stash), which this grid would catch
as a first-moment norm mismatch if either side double- or under-counted.

Fast tier: one dense pp2 × dp2 × tp2 run with ZeRO-1 on, plus the overlap
engine's A/B check — ``gate_compute=False`` replaces every ``lax.cond``
with compute-both + ``jnp.where`` (the pre-overlap masked executor) and
must agree with the gated step bit-for-bit, proving the cond gating
changes cost, never numerics.  Slow tier: pp{2,4} × tp2 × {dense,
MLA+MoE} × ZeRO-1, plus zb1p×SP composition.

Needs >1 fake device set before jax initialises — subprocess with XLA_FLAGS.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from test_sp_equivalence import HEADER  # noqa: F401  (reuse check())

ZB_FAST = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                    schedule="zb1p", zero=ZeROStage.OS)
    s2, m2 = jax.jit(step)(state, batch)
    check("ZB1P_PP2_DP2_TP2_ZOS", m1, s1, m2, s2)
""")

ZB_GATE_AB = HEADER + textwrap.dedent("""
    import numpy as np
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    mesh = jax.make_mesh((2, 2, 2), ("pipe", "data", "model"))
    outs = {}
    for sched in ("zb1p", "1f1b"):
        for gate in (True, False):
            step = make_pipeline_train_step(
                model, TrainConfig(n_micro=4), mesh, schedule=sched,
                zero=ZeROStage.OS, gate_compute=gate)
            outs[(sched, gate)] = jax.jit(step)(state, batch)
        (sg, mg), (su, mu) = outs[(sched, True)], outs[(sched, False)]
        assert float(mg["loss"]) == float(mu["loss"]), \
            (sched, float(mg["loss"]), float(mu["loss"]))
        for a, b in zip(jax.tree.leaves(sg.master),
                        jax.tree.leaves(su.master)):
            assert np.array_equal(jax.device_get(a), jax.device_get(b)), \
                f"{sched}: gated vs ungated master params differ bitwise"
        print(f"GATE_AB_{sched}_OK")
""")

ZB_DENSE_GRID = HEADER + textwrap.dedent("""
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)
    for pp, data, tp, sp in [(2, 2, 2, False), (4, 1, 2, False),
                             (2, 2, 2, True), (4, 1, 2, True)]:
        mesh = jax.make_mesh((pp, data, tp), ("pipe", "data", "model"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        schedule="zb1p", zero=ZeROStage.OS,
                                        sp=sp)
        s2, m2 = jax.jit(step)(state, batch)
        check(f"ZB1P_PP{pp}_DP{data}_TP{tp}_SP{int(sp)}", m1, s1, m2, s2)
""")

ZB_MOE_MLA = HEADER + textwrap.dedent("""
    from repro.models.transformer import ModelOptions
    # olmoe: all-MoE softmax router (routing noise gets the same wide loss
    # band the sp/pipeline suites grant it); deepseek: MLA latents + mixed
    # dense/MoE + shared expert.  capacity_factor=4.0 keeps routing
    # dropless so the comparison isolates the W-split, not capacity drops.
    for name, layers, tol in [("olmoe-1b-7b", 4, 1e-1),
                              ("deepseek-v3", 4, 5e-3)]:
        spec = dataclasses.replace(get_spec(name, smoke=True), n_layers=layers)
        model = build_model(spec, ModelOptions(capacity_factor=4.0))
        state = init_train_state(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(config_for(spec, 4, 32), 0)
        s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
        for pp, data, tp in [(2, 2, 2), (4, 1, 2)]:
            mesh = jax.make_mesh((pp, data, tp), ("pipe", "data", "model"))
            step = make_pipeline_train_step(model, TrainConfig(n_micro=2),
                                            mesh, schedule="zb1p",
                                            zero=ZeROStage.OS)
            s2, m2 = jax.jit(step)(state, batch)
            check(f"{name}_ZB1P_PP{pp}", m1, s1, m2, s2, tol_loss=tol)
""")


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


def test_zb1p_dense_fast():
    """pp2 × dp2 × tp2 with ZeRO-1: the tier-1 zb1p smoke."""
    r = _run(ZB_FAST)
    assert "ZB1P_PP2_DP2_TP2_ZOS_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


def test_gate_compute_ab_bitwise():
    """The overlap engine's cond gating is cost-only: gated (lax.cond) and
    ungated (compute-both + jnp.where) steps agree bit-for-bit on loss and
    post-update master params, for both the split (zb1p) and fused (1f1b)
    backward."""
    r = _run(ZB_GATE_AB)
    for tag in ["GATE_AB_zb1p_OK", "GATE_AB_1f1b_OK"]:
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_zb1p_dense_grid():
    """pp{2,4} × tp2 × sp{off,on} vs the single-device step."""
    r = _run(ZB_DENSE_GRID)
    for tag in ["ZB1P_PP2_DP2_TP2_SP0_OK", "ZB1P_PP4_DP1_TP2_SP0_OK",
                "ZB1P_PP2_DP2_TP2_SP1_OK", "ZB1P_PP4_DP1_TP2_SP1_OK"]:
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
def test_zb1p_moe_mla():
    """MoE (olmoe) and MLA+MoE (deepseek-v3) under zb1p at pp{2,4}."""
    r = _run(ZB_MOE_MLA)
    for tag in ["olmoe-1b-7b_ZB1P_PP2_OK", "olmoe-1b-7b_ZB1P_PP4_OK",
                "deepseek-v3_ZB1P_PP2_OK", "deepseek-v3_ZB1P_PP4_OK"]:
        assert tag in r.stdout, \
            f"missing {tag}\nstdout={r.stdout}\nstderr={r.stderr[-3000:]}"
