"""ZB-H1 zero-bubble schedule invariants (``schedule="zb1p"``).

The zero-bubble family (Qi et al.) splits each backward into B (input
gradient, on the critical path) and W (weight gradient, free to slide into
bubbles).  ZB-H1 keeps 1F1B's activation residency — B still retires the
microbatch's activations — and fills the 1F1B cooldown with W ticks, so
its canonical bubble is strictly smaller for pp >= 2 while its in-flight
peak per rank is exactly 1F1B's ``min(M, pp - r)``.

Verified here, deterministically over the ``test_schedules.py``-style grid
and widened by hypothesis when installed:

* exactly-once F, B *and* W per (microbatch, stage); W strictly after its
  B; all of ``PipelineSchedule.check()``'s dep/capacity invariants;
* closed forms: canonical makespan ``3M + 2(pp-1) - min(M-1, pp-1)``,
  per-rank in-flight peak ``min(M, pp-r)`` == ``schedule_in_flight`` ==
  the simulated ``in_flight_series`` peak; the executor timeline gives W
  dedicated cond-gated ticks (never sharing a rank-tick with that rank's
  own F or B — exactly M of each kind per rank);
* ``core.steptime.bubble_fraction``: zb1p <= 1f1b at equal (pp, M), with
  the canonical idle count ``2(pp-1) - min(M-1, pp-1)`` per rank;
* the executor tables route zb1p's boundary tensors exactly as 1f1b's
  (W adds no traffic), and ``w_act``/``w_micro``/``w_chunk`` mark each
  (m, stage) exactly once, strictly after its B tick, flushing the stash
  slot (``w_sidx``) its B wrote (``b_sidx``) — ring depth ``s_slots`` ==
  the peak of ``zb_pending_peak``;
* ``estimate_memory(schedule="zb1p")`` carries the B→W pending-dW stash
  in the grads column (activations match 1f1b — B runs the full vjp and
  retires the microbatch), and the planner prices zb1p configs via
  ``predicted_step_s``.
"""

import numpy as np
import pytest

from repro.core.activations import schedule_in_flight
from repro.core.schedules import (PipelineSchedule, exec_tick_times,
                                  make_schedule)
from repro.core.steptime import bubble_fraction, bubble_stats, exec_ticks
from repro.train.schedules import build_exec_tables

GRID = [(pp, m) for pp in (1, 2, 3, 4, 5) for m in (1, 2, 4, 5, 8)]


def _canonical_makespan(sched: PipelineSchedule) -> int:
    return max(op.t for op in sched.ticks) + 1


@pytest.mark.parametrize("pp,m", GRID)
def test_zb1p_invariants_and_closed_forms(pp, m):
    sched = make_schedule("zb1p", pp, m)
    sched.check()   # exactly-once F/B/W, W after B, deps, rank capacity
    # in-flight peak: B retires activations, so residency is exactly 1F1B's
    peaks = [sched.rank_peak_in_flight(r) for r in range(pp)]
    assert peaks == [min(m, pp - r) for r in range(pp)]
    assert peaks == [schedule_in_flight(pp, r, m, schedule="zb1p")
                     for r in range(pp)]
    # canonical makespan: 3 ops per micro on the last rank, 2(pp-1) ramp,
    # minus the W ops that overlap the cooldown
    assert _canonical_makespan(sched) == \
        3 * m + 2 * (pp - 1) - min(m - 1, pp - 1)


@pytest.mark.parametrize("pp,m", GRID)
def test_zb1p_bubble_below_1f1b(pp, m):
    zb = bubble_stats("zb1p", pp, m)
    base = bubble_stats("1f1b", pp, m)
    assert zb.bubble_fraction <= base.bubble_fraction + 1e-12
    if pp >= 2 and m >= 2:
        assert zb.bubble_fraction < base.bubble_fraction
    # canonical idle per rank: the 1f1b warmup/cooldown 2(pp-1) minus the
    # min(M-1, pp-1) slots W fills
    sched = make_schedule("zb1p", pp, m)
    T = _canonical_makespan(sched)
    per_rank_ops = [0] * pp
    for op in sched.ticks:
        per_rank_ops[op.rank] += 1
    for r in range(pp):
        assert T - per_rank_ops[r] == 2 * (pp - 1) - min(m - 1, pp - 1)


@pytest.mark.parametrize("pp,m", [(2, 2), (2, 4), (3, 5), (4, 4), (4, 8)])
def test_zb1p_exec_w_only_ticks(pp, m):
    """The overlap engine gives W its own cond-gated tick: a rank's W never
    shares a tick with that rank's own F or B, so zb1p's timeline is
    strictly longer than 1f1b's — per rank exactly M F-ticks, M B-ticks and
    M W-ticks, cond-gated so the extra ticks only cost W's work."""
    assert exec_ticks("zb1p", pp, m) > exec_ticks("1f1b", pp, m)
    tab = build_exec_tables(make_schedule("zb1p", pp, m))
    for r in range(pp):
        assert int(tab.f_act[:, r].sum()) == m
        assert int(tab.b_act[:, r].sum()) == m
        assert int(tab.w_act[:, r].sum()) == m
        # dedicated W ticks: no rank-tick carries W alongside its own F/B
        clash = (tab.w_act[:, r] > 0) & \
            ((tab.f_act[:, r] > 0) | (tab.b_act[:, r] > 0))
        assert not clash.any()


@pytest.mark.parametrize("pp,m", [(2, 4), (3, 5), (4, 8)])
def test_zb1p_exec_tables(pp, m):
    sched = make_schedule("zb1p", pp, m)
    tab = build_exec_tables(sched)
    assert tab.w_act is not None
    # every (micro, rank) W fires exactly once, strictly after its B, and
    # flushes exactly the stash slot its B wrote the pending-dW into; no
    # two microbatches pending at once on a rank share a slot (interval
    # colouring), and the ring depth is the schedule-wide peak pendency
    from repro.core.schedules import zb_pending_peak
    assert tab.s_slots == max(zb_pending_peak(pp, m))
    times = exec_tick_times(sched)
    seen = set()
    b_slot = {}
    for t in range(tab.T):
        for r in range(pp):
            if tab.b_act[t, r] > 0:
                b_slot[(int(tab.b_micro[t, r]), r)] = int(tab.b_sidx[t, r])
            if tab.w_act[t, r] > 0:
                mm = int(tab.w_micro[t, r])
                assert (mm, r) not in seen
                seen.add((mm, r))
                assert times[("B", mm, r)] < t    # strictly after its B
                assert int(tab.w_chunk[t, r]) == 0
                assert int(tab.w_sidx[t, r]) == b_slot[(mm, r)]
    assert seen == {(mm, r) for mm in range(m) for r in range(pp)}
    # no-overlap: microbatches whose B→W windows intersect on a rank get
    # distinct stash slots
    for r in range(pp):
        wins = [(times[("B", mm, r)], times[("W", mm, r)], b_slot[(mm, r)])
                for mm in range(m)]
        for i, (b1, w1, s1) in enumerate(wins):
            for b2, w2, s2 in wins[i + 1:]:
                if b1 < w2 and b2 < w1:
                    assert s1 != s2
    # 1f1b activates no W columns
    base = build_exec_tables(make_schedule("1f1b", pp, m))
    assert base.w_act is None or not np.any(base.w_act)


def test_zb1p_boundary_routing_matches_1f1b():
    """W moves no boundary tensors: the x/g ring routing replay of
    ``test_schedules.py`` holds verbatim for zb1p."""
    from test_schedules import _check_exec_routing
    for pp, m in [(2, 4), (3, 5), (4, 8)]:
        _check_exec_routing(make_schedule("zb1p", pp, m))


def test_zb1p_needs_single_chunk():
    with pytest.raises(ValueError):
        make_schedule("zb1p", 4, 8, n_chunks=2)


def test_zb1p_memory_carries_pending_stash():
    """estimate_memory(schedule='zb1p'): activations/params/optimizer match
    1f1b's (B runs the full vjp, so residency is identical); the grads
    column carries the B→W stash — one fp32 copy of the rank's per-layer
    (non-shared) grads per pending microbatch, allocated uniformly at the
    schedule-wide ``max(zb_pending_peak)`` (the executor's scan-carried
    stash ring depth, ``ExecTables.s_slots``)."""
    from repro.configs import get_spec
    from repro.core import estimate_memory
    from repro.core.parallel_config import ParallelConfig, ZeROStage
    from repro.core.params import device_params
    from repro.core.activations import rank_chunk_layers
    from repro.core.schedules import zb_pending_peak

    spec = get_spec("qwen2-1.5b")
    cfg = ParallelConfig(dp=2, tp=2, pp=2, zero=ZeROStage.OS,
                         micro_batch=1, seq_len=2048)
    pend = max(zb_pending_peak(cfg.pp, 2 * cfg.pp))
    for r in range(cfg.pp):
        zb = estimate_memory(spec, cfg, stage=r, schedule="zb1p")
        base = estimate_memory(spec, cfg, stage=r, schedule="1f1b")
        assert zb.activations == base.activations
        assert zb.params == base.params and zb.optimizer == base.optimizer
        layers = [l for ls in rank_chunk_layers(spec, cfg.pp,
                                                schedule="zb1p")[r]
                  for l in ls]
        dev = device_params(spec, cfg, layers=layers)
        stash = pend * (dev.total - dev.embed) * 4    # fp32 layer grads
        assert zb.grads == base.grads + stash
        assert stash > 0


def test_planner_prices_zb1p():
    from repro.configs import get_spec
    from repro.core import plan

    spec = get_spec("qwen2-1.5b")
    entries = plan(spec, 8, 80 * 2**30, seq_len=2048, top_k=50,
                   schedule="zb1p")
    priced = [e for e in entries if e.runnable and e.cfg.pp > 1]
    assert priced, "no runnable pp>1 zb1p configs priced"
    assert all(e.predicted_step_s and e.predicted_step_s > 0 for e in priced)
    # runnable entries lead and are sorted by predicted step time
    preds = [e.predicted_step_s for e in entries if e.runnable
             and e.predicted_step_s is not None]
    assert preds == sorted(preds)


# ---------------------------------------------------------------------------
# Property-based widening (mirrors test_schedules.py: skipped without
# hypothesis, deterministic grid above unaffected)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(pp=st.integers(1, 6), m=st.integers(1, 12))
    def test_hyp_zb1p(pp, m):
        sched = make_schedule("zb1p", pp, m)
        sched.check()
        assert [sched.rank_peak_in_flight(r) for r in range(pp)] == \
            [min(m, pp - r) for r in range(pp)]
        assert _canonical_makespan(sched) == \
            3 * m + 2 * (pp - 1) - min(m - 1, pp - 1)
        assert bubble_fraction("zb1p", pp, m) <= \
            bubble_fraction("1f1b", pp, m) + 1e-12
        if pp > 1:
            from test_schedules import _check_exec_routing
            _check_exec_routing(sched)
            # closed-form work totals: the exec tables give every rank
            # exactly M F-ticks, M B-ticks and M W-ticks — no W rides a
            # B tick, none goes missing
            tab = build_exec_tables(sched)
            for r in range(pp):
                assert int((tab.f_act[:, r] > 0).sum()) == m
                assert int((tab.b_act[:, r] > 0).sum()) == m
                assert int((tab.w_act[:, r] > 0).sum()) == m
