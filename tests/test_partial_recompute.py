"""Partial recomputation (paper §5's 'how many layers / which layers')."""

import dataclasses

import jax
import pytest

from repro.configs import get_spec
from repro.core import PAPER_CONFIG, RecomputePolicy, stage_activation_bytes
from repro.data.synthetic import config_for, make_batch
from repro.models import build_model
from repro.models.transformer import ModelOptions

SPEC = get_spec("deepseek-v3")


def test_analytic_monotone_in_fraction():
    vals = []
    for f in (0.0, 0.25, 0.5, 0.75, 1.0):
        cfg = dataclasses.replace(PAPER_CONFIG,
                                  recompute=RecomputePolicy.FULL,
                                  recompute_fraction=f)
        vals.append(stage_activation_bytes(SPEC, cfg))
    assert vals == sorted(vals, reverse=True)
    # f=0 == AC-None; f=1 == the paper's AC-Full row
    none_cfg = dataclasses.replace(PAPER_CONFIG,
                                   recompute=RecomputePolicy.NONE)
    assert vals[0] == stage_activation_bytes(SPEC, none_cfg)
    full_cfg = dataclasses.replace(PAPER_CONFIG,
                                   recompute=RecomputePolicy.FULL,
                                   recompute_fraction=1.0)
    assert vals[-1] == stage_activation_bytes(SPEC, full_cfg)


def test_analytic_interpolates_linearly():
    cfg_half = dataclasses.replace(PAPER_CONFIG,
                                   recompute=RecomputePolicy.FULL,
                                   recompute_fraction=0.5)
    a_half = stage_activation_bytes(SPEC, cfg_half)
    a_none = stage_activation_bytes(
        SPEC, dataclasses.replace(PAPER_CONFIG,
                                  recompute=RecomputePolicy.NONE))
    a_full = stage_activation_bytes(
        SPEC, dataclasses.replace(PAPER_CONFIG,
                                  recompute=RecomputePolicy.FULL))
    assert a_half == (a_none + a_full) // 2  # 4-layer stage: 2+2


@pytest.mark.parametrize("frac", [0.0, 0.5, 1.0])
def test_runtime_numerics_invariant(frac):
    spec = get_spec("qwen2-1.5b", smoke=True)
    batch = make_batch(config_for(spec, 2, 32), 0)
    ref = build_model(spec, ModelOptions())
    mod = build_model(spec, ModelOptions(recompute=RecomputePolicy.FULL,
                                         recompute_fraction=frac))
    params = ref.init(jax.random.PRNGKey(0))
    l0, _ = jax.jit(ref.loss)(params, batch)
    l1, _ = jax.jit(mod.loss)(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-3
    # gradients too (the remat path changes the backward structure)
    g0 = jax.jit(jax.grad(lambda p: ref.loss(p, batch)[0]))(params)
    g1 = jax.jit(jax.grad(lambda p: mod.loss(p, batch)[0]))(params)
    import numpy as np
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=5e-2)
