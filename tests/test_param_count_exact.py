"""The analytical model and the runtime must agree to the PARAMETER: for
every architecture, ModelSpec.total_params() == the abstract-init leaf sum.
This is the contract that makes the memory model trustworthy (DESIGN.md §2).
"""

import math

import jax
import pytest

from repro.configs import ARCHS, get_spec
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("smoke", [True, False])
def test_runtime_matches_analytic_param_count(arch, smoke):
    spec = get_spec(arch, smoke=smoke)
    ap = build_model(spec).abstract_params()
    runtime = sum(math.prod(l.shape) for l in jax.tree.leaves(ap))
    assert runtime == spec.total_params(), (
        f"{arch} smoke={smoke}: runtime {runtime:,} != "
        f"analytic {spec.total_params():,} "
        f"(diff {runtime - spec.total_params():,})")


def test_deepseek_paper_vs_dedup_count():
    """Paper's Table-3 total includes the qk-norm double count (61×2048) and
    omits the final norm (7168); the de-duplicated truth differs by exactly
    that."""
    from repro.core.params import total_params_paper
    spec = get_spec("deepseek-v3")
    paper = total_params_paper(spec)
    exact = spec.total_params()
    assert paper - exact == 61 * 2048 - 7168


def test_active_params_moe():
    spec = get_spec("deepseek-v3")
    active = spec.active_params()
    # DeepSeek-v3: ~37B activated of 671B total
    assert 35e9 < active < 40e9, active / 1e9
    olmoe = get_spec("olmoe-1b-7b")
    # OLMoE: ~1.3B active of ~6.9B total
    assert 0.9e9 < olmoe.active_params() < 1.7e9
