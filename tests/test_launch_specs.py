"""launch.specs unit tests: input shapes, skip logic, cache placement."""

import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_spec
from repro.launch.specs import (SHAPES, SLIDING_WINDOW_LONG, batch_specs,
                                cache_divisor, cache_placement, input_specs,
                                shape_skip_reason, spec_for_shape)


def test_shapes_pool_exact():
    assert SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert SHAPES["prefill_32k"] == dict(kind="prefill", seq=32768, batch=32)
    assert SHAPES["decode_32k"] == dict(kind="decode", seq=32768, batch=128)
    assert SHAPES["long_500k"] == dict(kind="decode", seq=524288, batch=1)


def test_skip_only_whisper_long():
    skips = [(a, s) for a in ASSIGNED for s in SHAPES
             if shape_skip_reason(get_spec(a), s)]
    assert skips == [("whisper-tiny", "long_500k")]


def test_long_500k_variants():
    # SSM/hybrid run natively; dense/MoE/VLM get the sliding window
    assert spec_for_shape(get_spec("rwkv6-1.6b"), "long_500k").sliding_window is None
    assert spec_for_shape(get_spec("hymba-1.5b"), "long_500k").sliding_window is None
    for a in ("gemma-2b", "qwen2-vl-72b", "olmoe-1b-7b"):
        v = spec_for_shape(get_spec(a), "long_500k")
        assert v.sliding_window == SLIDING_WINDOW_LONG
    # other shapes unmodified
    assert spec_for_shape(get_spec("gemma-2b"), "decode_32k").sliding_window is None


def test_batch_specs_frontend_stubs():
    vl = batch_specs(get_spec("qwen2-vl-72b"), 4, 1024)
    assert "vision_embeds" in vl
    assert vl["vision_embeds"].shape == (4, 256, 8192)
    wh = batch_specs(get_spec("whisper-tiny"), 4, 128)
    assert wh["audio_embeds"].shape == (4, 1500, 384)
    dense = batch_specs(get_spec("gemma-2b"), 4, 128)
    assert set(dense) == {"tokens"}


def test_decode_input_specs_cache_len():
    ins = input_specs(get_spec("qwen2-1.5b"), "decode_32k")
    k = ins["cache"]["kv"]["k"]
    assert k.shape == (28, 128, 32768, 2, 128)
    assert ins["tokens"].shape == (128, 1)
    # long_500k sliding window caps the cache
    ins = input_specs(get_spec("qwen2-1.5b"), "long_500k")
    assert ins["cache"]["kv"]["k"].shape[2] == SLIDING_WINDOW_LONG


def test_cache_placement_prefers_heads_then_seq():
    # kv heads divisible -> heads sharded
    assert cache_placement((28, 128, 32768, 16, 128), 16, 16) == \
        (None, "batch", None, "model", None)
    # kv heads NOT divisible -> seq sharded (hillclimb 3 lesson)
    assert cache_placement((28, 128, 32768, 2, 128), 16, 16) == \
        (None, "batch", "model", None, None)
    # b=1 long-context: context-parallel batch on seq, model moves on
    p = cache_placement((28, 1, 8192, 2, 128), 16, 16)
    assert p[1] is None and p[2] == "batch"
    # scalar / index leaves
    assert cache_placement((), 16, 16) == ()


def test_cache_divisor_consistency():
    shape = (28, 128, 32768, 16, 128)
    assert cache_divisor(shape, 16, 16) == 256
    assert cache_divisor((28, 1, 8192, 2, 128), 16, 16) >= 16


def test_pp_in_flight_microbatches_scale_activation():
    from repro.core import PAPER_CONFIG, stage_activation_bytes
    spec = get_spec("deepseek-v3")
    a1 = stage_activation_bytes(spec, PAPER_CONFIG, in_flight=1)
    a16 = stage_activation_bytes(spec, PAPER_CONFIG, in_flight=16)
    assert a16 == 16 * a1   # 1F1B worst-case residency multiplier
