"""use_pallas=True routes the model's RMSNorm + attention through the
Pallas kernels (interpret mode on CPU) and must match the jnp path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.data.synthetic import config_for, make_batch
from repro.models import build_model
from repro.models.transformer import ModelOptions


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v3", "gemma-2b"])
def test_pallas_model_matches_jnp(arch):
    spec = get_spec(arch, smoke=True)
    m_ref = build_model(spec, ModelOptions(use_pallas=False))
    m_pal = build_model(spec, ModelOptions(use_pallas=True))
    params = m_ref.init(jax.random.PRNGKey(0))
    batch = make_batch(config_for(spec, 2, 32), 0)
    ref_logits, _ = jax.jit(m_ref.forward)(params, batch)
    pal_logits, _ = jax.jit(m_pal.forward)(params, batch)
    np.testing.assert_allclose(
        np.asarray(pal_logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.2, rtol=0.2)   # bf16 model; kernels accumulate fp32
    # agreement should be much tighter than logit scale
    diff = np.abs(np.asarray(pal_logits - ref_logits, np.float32)).max()
    scale = np.abs(np.asarray(ref_logits, np.float32)).max()
    assert diff < 0.05 * max(scale, 1.0), (diff, scale)
