"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.moe_gmm import pad_groups

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (3, 7, 256), (1, 384),
                                   (300, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("gemma", [False, True])
def test_rmsnorm_matches_ref(shape, dtype, gemma):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(k1, shape, dtype)
    scale = _rand(k2, shape[-1:], dtype) * 0.1
    got = ops.rmsnorm(x, scale, gemma_style=gemma, interpret=True)
    want = ref.rmsnorm_ref(x, scale, gemma_style=gemma)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_rows", [8, 64, 256])
def test_rmsnorm_block_sweep(block_rows):
    x = _rand(jax.random.PRNGKey(1), (100, 256), jnp.float32)
    s = jnp.ones((256,), jnp.float32)
    got = ops.rmsnorm(x, s, block_rows=block_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.rmsnorm_ref(x, s)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention (MLA-shaped: dq != dv supported)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,nh,dq,dv", [
    (1, 128, 2, 64, 64),
    (2, 256, 4, 128, 128),
    (1, 200, 2, 192, 128),      # MLA geometry (d_h+d_hr=192, d_v=128), ragged s
    (2, 64, 1, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(b, s, nh, dq, dv, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(k1, (b, s, nh, dq), dtype)
    k = _rand(k2, (b, s, nh, dq), dtype)
    v = _rand(k3, (b, s, nh, dv), dtype)
    scale = dq ** -0.5
    got = ops.flash_attention(q, k, v, scale=scale, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=scale)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_non_causal():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(k1, (1, 128, 2, 64), jnp.float32)
    k = _rand(k2, (1, 128, 2, 64), jnp.float32)
    v = _rand(k3, (1, 128, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, scale=0.125, causal=False,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.125, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block", [32, 128])
def test_flash_block_sweep(block):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(k1, (1, 257, 2, 64), jnp.float32)   # deliberately ragged
    k = _rand(k2, (1, 257, 2, 64), jnp.float32)
    v = _rand(k3, (1, 257, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, scale=0.125, block_q=block,
                              block_k=block, interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Kernel, jnp-chunked (model path), and naive oracle must all agree."""
    from repro.models.attention import chunked_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(k1, (2, 96, 2, 64), jnp.float32)
    k = _rand(k2, (2, 96, 2, 64), jnp.float32)
    v = _rand(k3, (2, 96, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, scale=0.125, interpret=True)
    b = chunked_attention(q, k, v, 0.125, block=32)
    c = ref.flash_attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,K,N,block_m", [
    (4, 64, 128, 16),
    (8, 128, 256, 32),
    (3, 96, 64, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_matches_ref(E, K, N, block_m, dtype):
    rng = np.random.default_rng(0)
    group_sizes = rng.integers(0, 3 * block_m, size=E)
    rows = np.repeat(np.arange(E), group_sizes)
    T = len(rows)
    x = _rand(jax.random.PRNGKey(6), (max(T, 1), K), dtype)
    rhs = _rand(jax.random.PRNGKey(7), (E, K, N), dtype)
    lhs, emap, ridx = pad_groups(x[:T], group_sizes, block_m)
    got = ops.gmm(lhs, rhs, jnp.asarray(emap), block_m=block_m,
                  block_n=min(128, N), interpret=True)
    want = ref.gmm_ref(lhs, rhs, emap, block_m=block_m)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    # padded rows scatter back losslessly
    valid = ridx >= 0
    assert valid.sum() == T


def test_gmm_against_dense_expert_loop():
    """GMM == looping each expert over its slab (semantic oracle)."""
    E, K, N, block_m = 4, 32, 64, 8
    sizes = np.array([8, 16, 0, 24])
    x = _rand(jax.random.PRNGKey(8), (int(sizes.sum()), K), jnp.float32)
    rhs = _rand(jax.random.PRNGKey(9), (E, K, N), jnp.float32)
    lhs, emap, ridx = pad_groups(x, sizes, block_m)
    got = ops.gmm(lhs, rhs, jnp.asarray(emap), block_m=block_m, block_n=64,
                  interpret=True)
    got_valid = np.asarray(got)[ridx >= 0]
    want = []
    off = 0
    for e in range(E):
        g = int(sizes[e])
        want.append(np.asarray(x[off:off + g] @ rhs[e]))
        off += g
    np.testing.assert_allclose(got_valid, np.concatenate(want), atol=1e-4,
                               rtol=1e-4)
