"""Pipeline stage partitioning: the runtime's layer→stage assignment must be
the analytical model's (Table 4), per-stage forwards must compose to the
pp=1 forward bit-for-bit, and the stacked SPMD layout must round-trip."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.core import estimate_memory, one_f1b_in_flight, plan
from repro.core.params import table4_stages
from repro.core.parallel_config import ParallelConfig
from repro.models import build_model
from repro.models.pipeline import (check_pipeline_supported, make_stage_fn,
                                   partition, stack_pipeline_params,
                                   stage_params_slice, unstack_pipeline_grads)


def _smoke(name, n_layers=None):
    spec = get_spec(name, smoke=True)
    if n_layers and spec.n_layers != n_layers:
        spec = dataclasses.replace(spec, n_layers=n_layers)
    return spec


def test_partition_matches_table4():
    for name, pp in [("qwen2-1.5b", 2), ("deepseek-v3", 2), ("deepseek-v3", 4)]:
        spec = _smoke(name, 4)
        part = partition(spec, pp)
        assert [list(s) for s in part.stages] == \
            [list(r.layers) for r in table4_stages(spec, pp)]
    # the paper's PP16 split of the full 61-layer model: 15×4 + 1
    ds = get_spec("deepseek-v3")
    part = partition(ds, 16)
    assert [len(s) for s in part.stages] == [4] * 15 + [1]


def test_partition_slot_masks():
    part = partition(get_spec("deepseek-v3"), 16)
    assert part.mask.shape == (16, 4)
    assert part.mask[:15].all() and part.mask[15, 0] == 1.0 \
        and not part.mask[15, 1:].any()
    # every layer owned exactly once
    owned = [part.stages[part.stage_of[l]][part.slot_of[l]]
             for l in range(part.n_layers)]
    assert owned == list(range(part.n_layers))


@pytest.mark.parametrize("name,pp", [("qwen2-1.5b", 2), ("qwen2-1.5b", 4),
                                     ("deepseek-v3", 2), ("olmoe-1b-7b", 2)])
def test_stage_chain_equals_full_forward(name, pp):
    """Composing the heterogeneous per-stage forwards reproduces Model.forward
    exactly — the contract the per-stage dry-run programs rely on."""
    spec = _smoke(name, 4)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, spec.vocab)
    x, aux = None, 0.0
    for s in range(pp):
        x, a = make_stage_fn(spec, model.opts, pp, s)(
            stage_params_slice(params, spec, pp, s), x, toks)
        aux = aux + a
    logits, ref_aux = model.forward(params, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(logits, np.float32))
    np.testing.assert_allclose(float(aux), float(ref_aux), rtol=1e-6)


def test_stage_params_place_embed_and_head():
    spec = _smoke("deepseek-v3")          # untied: distinct head
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    s0 = stage_params_slice(params, spec, 2, 0)
    s1 = stage_params_slice(params, spec, 2, 1)
    assert "embed" in s0 and "embed" not in s1
    assert "final_norm" in s1 and "final_norm" not in s0
    assert ("head" in s1) == ("head" in params)


@pytest.mark.parametrize("name", ["qwen2-1.5b", "deepseek-v3", "olmoe-1b-7b"])
def test_stack_unstack_roundtrip(name):
    """unstack(stack(params)) == params leaf-for-leaf (tied embeddings sum
    their stage-0 and last-stage rows — the gradient-flow contract)."""
    spec = _smoke(name, 4)
    pp = 2
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    rt = unstack_pipeline_grads(stack_pipeline_params(params, spec, pp),
                                params, spec, pp)
    fa, ta = jax.tree_util.tree_flatten(params)
    fb, tb = jax.tree_util.tree_flatten(rt)
    assert ta == tb
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(params), fb):
        mult = 2.0 if (spec.tie_embeddings and "embed" in str(path)) else 1.0
        np.testing.assert_array_equal(
            np.asarray(a, np.float32) * mult, np.asarray(b, np.float32))


@pytest.mark.parametrize("name", ["qwen2-1.5b", "deepseek-v3"])
@pytest.mark.parametrize("sched,v", [("interleaved", 2), ("dualpipe", 2)])
def test_chunked_stack_unstack_roundtrip(name, sched, v):
    """The chunk-stacked layouts round-trip like the plain one, except that
    dualpipe duplicates every layer across two ranks (gradients sum both
    copies — the schedule's 2x parameter cost), and embed/head rows sum
    over the ranks owning a first/last model chunk."""
    from repro.models.pipeline import chunked_partition
    spec = _smoke(name, 4)
    pp = 2
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    part = chunked_partition(spec, pp, schedule=sched, n_chunks=v)
    rt = unstack_pipeline_grads(
        stack_pipeline_params(params, spec, pp, schedule=sched, n_chunks=v),
        params, spec, pp, schedule=sched, n_chunks=v)
    emb_ranks = {r for r in range(pp) for c in range(part.n_chunks)
                 if part.first_flag[r, c]
                 or (spec.tie_embeddings and part.last_flag[r, c])}
    head_ranks = {r for r in range(pp) for c in range(part.n_chunks)
                  if part.last_flag[r, c]}
    for (path, a), b in zip(jax.tree_util.tree_leaves_with_path(params),
                            jax.tree.leaves(rt)):
        p = str(path)
        if "dense_layers" in p or "moe_layers" in p:
            mult = 2.0 if sched == "dualpipe" else 1.0
        elif "embed" in p:
            mult = float(len(emb_ranks))
        elif "final_norm" in p or "head" in p:
            mult = float(len(head_ranks))
        else:
            mult = 1.0
        np.testing.assert_array_equal(
            np.asarray(a, np.float32) * mult, np.asarray(b, np.float32))


def test_chunked_partition_matches_schedule_placement():
    """Runtime chunk layout and analytic accounting share one placement."""
    from repro.core import rank_chunk_layers, schedule_placement
    from repro.models.pipeline import chunked_partition
    spec = _smoke("qwen2-1.5b", 8)
    for sched, v in [("1f1b", 1), ("interleaved", 2), ("dualpipe", 2)]:
        part = chunked_partition(spec, 4, schedule=sched, n_chunks=v)
        assert part.placement == schedule_placement(sched, 4, v)
        assert part.chunks == rank_chunk_layers(spec, 4, schedule=sched,
                                                n_chunks=v)


def test_pipeline_unsupported_families():
    for name in ("rwkv6-1.6b", "whisper-tiny", "qwen2-vl-72b"):
        with pytest.raises(NotImplementedError):
            check_pipeline_supported(get_spec(name, smoke=True))


def test_one_f1b_in_flight():
    assert [one_f1b_in_flight(4, s) for s in range(4)] == [4, 3, 2, 1]
    assert one_f1b_in_flight(4, 0, n_micro=2) == 2
    assert one_f1b_in_flight(16, 15, n_micro=64) == 1
    with pytest.raises(ValueError):
        one_f1b_in_flight(4, 4)


def test_estimate_memory_in_flight_scales_stage0():
    spec = get_spec("deepseek-v3")
    cfg = ParallelConfig(dp=32, tp=2, pp=16, ep=8, etp=1, sp=True,
                         micro_batch=1, seq_len=4096)
    base = [estimate_memory(spec, cfg, stage=s,
                            in_flight_microbatches=one_f1b_in_flight(16, s)
                            ).activations for s in (0, 15)]
    assert base[0] >= base[1]
    flat = estimate_memory(spec, cfg, stage=0).activations
    assert base[0] == 16 * flat * \
        estimate_memory(spec, cfg, stage=0,
                        in_flight_microbatches=1).activations / flat


def test_schedule_planner_guards():
    """Schedule-aware planning rejects invalid arguments loudly and never
    admits configs the executor would refuse."""
    from repro.core import rank_chunk_layers
    spec = dataclasses.replace(get_spec("qwen2-1.5b"), n_layers=4)
    budget = 64 * 2 ** 30
    # interleaved with default n_chunks=1 is a caller error, not "no fit"
    with pytest.raises(ValueError):
        plan(spec, 8, budget, schedule="interleaved")
    # pp*v > n_layers configs are skipped, feasible pp values survive
    entries = plan(spec, 8, budget, top_k=64, schedule="interleaved",
                   n_chunks=2)
    assert entries and all(e.cfg.pp * 2 <= spec.n_layers or e.cfg.pp == 1
                           for e in entries)
    with pytest.raises(ValueError):
        rank_chunk_layers(spec, 8, schedule="interleaved", n_chunks=2)
    # dualpipe pp=1 would silently double the whole model onto one rank
    with pytest.raises(ValueError):
        rank_chunk_layers(spec, 1, schedule="dualpipe", n_chunks=2)
    with pytest.raises(ValueError):
        estimate_memory(spec, ParallelConfig(pp=1), stage=0,
                        schedule="dualpipe", n_chunks=2)
    # schedule-aware accounting is training-only
    with pytest.raises(ValueError):
        estimate_memory(spec, ParallelConfig(pp=2), stage=0,
                        schedule="1f1b", training=False)
    # the legacy residency knob conflicts with the schedule path
    with pytest.raises(ValueError):
        estimate_memory(spec, ParallelConfig(pp=2), stage=0,
                        schedule="1f1b", in_flight_microbatches=4)


def test_planner_headroom_and_pp_in_flight():
    spec = get_spec("qwen2-1.5b")
    budget = 32 * 2 ** 30
    entries = plan(spec, 64, budget, top_k=5)
    assert entries
    for e in entries:
        assert e.budget == budget
        assert e.headroom == budget - e.estimate.total > 0
    # 1F1B residency must not make a pp>1 config look lighter than the
    # single-microbatch view
    flat = plan(spec, 64, budget, top_k=64, pp_in_flight=False)
    by_cfg = {e.cfg: e for e in flat}
    for e in plan(spec, 64, budget, top_k=64, pp_in_flight=True):
        if e.cfg in by_cfg and e.cfg.pp > 1:
            assert e.estimate.activations >= by_cfg[e.cfg].estimate.activations
