"""Schedule-driven pipeline executor vs the pp=1 train loop: same loss and
post-update master params within bf16-accumulation tolerance on fake-device
meshes, for every schedule (1f1b / interleaved / dualpipe) at pp ∈ {2, 4}.

Needs >1 fake device set before jax initialises — subprocess with XLA_FLAGS.
"""

import os
import subprocess
import sys
import textwrap

import pytest

DENSE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    SCHEDULE = {schedule!r}
    N_CHUNKS = {n_chunks}
    # interleaved pp=4 needs pp*v=8 model chunks -> 8 layers
    spec = dataclasses.replace(get_spec("qwen2-1.5b", smoke=True), n_layers=8)
    model = build_model(spec)
    state = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, 8, 32), 0)
    # loss mask exercises the masked-CE path on both executors
    batch["mask"] = jnp.broadcast_to(
        (jnp.arange(32) < 28).astype(jnp.float32)[None], (8, 32))
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=4)))(state, batch)

    for pp, data in [(2, 2), (4, 2)]:
        mesh = jax.make_mesh((pp, data), ("pipe", "data"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=4), mesh,
                                        schedule=SCHEDULE, n_chunks=N_CHUNKS)
        s2, m2 = jax.jit(step)(state, batch)
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < 5e-3, f"pp={{pp}}: loss diverged {{dl}}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < 2e-2, f"pp={{pp}}: master params diverged {{worst}}"
        print(f"PP{{pp}}_OK", dl, worst)
""")

MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_spec
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig, make_train_step
    from repro.train.pipeline_loop import make_pipeline_train_step

    SCHEDULE = {schedule!r}
    N_CHUNKS = {n_chunks}
    # olmoe: all-MoE layers; deepseek: mixed dense+MoE with MLA — exercises
    # the union-slot select path end to end.  Both padded to 4 layers so
    # every schedule fits its chunk count (interleaved pp=2 v=2 -> 4 chunks).
    # olmoe's loss tolerance is routing noise, not executor error: bf16
    # differences between the stacked and pp=1 layouts flip top-k expert
    # picks, shifting the *metric* ~1.5e-2/layer while post-update params
    # still agree to 6e-4 (the strict check below); identical across all
    # three schedules.
    for name, layers, data, tol in [("olmoe-1b-7b", 4, 2, 1e-1),
                                    ("deepseek-v3", 4, 1, 1e-3)]:
        spec = get_spec(name, smoke=True)
        if layers and spec.n_layers != layers:
            spec = dataclasses.replace(spec, n_layers=layers)
        model = build_model(spec)
        state = init_train_state(model.init(jax.random.PRNGKey(0)))
        batch = make_batch(config_for(spec, 4, 32), 0)
        s1, m1 = jax.jit(make_train_step(model, TrainConfig(n_micro=2)))(state, batch)
        mesh = jax.make_mesh((2, data), ("pipe", "data"))
        step = make_pipeline_train_step(model, TrainConfig(n_micro=2), mesh,
                                        schedule=SCHEDULE, n_chunks=N_CHUNKS)
        s2, m2 = jax.jit(step)(state, batch)
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        assert dl < tol, f"{{name}}: loss diverged {{dl}}"
        worst = max(float(jnp.abs(a - jax.device_get(b)).max())
                    for a, b in zip(jax.tree.leaves(s1.master),
                                    jax.tree.leaves(s2.master)))
        assert worst < 2e-2, f"{{name}}: master params diverged {{worst}}"
        print(f"{{name}}_MOE_OK", dl, worst)
""")

SCHEDULES = [("1f1b", 1), ("interleaved", 2), ("dualpipe", 2)]


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560,
                          cwd=os.path.dirname(os.path.dirname(__file__)))


@pytest.mark.parametrize("schedule,n_chunks", SCHEDULES)
def test_pipeline_matches_pp1_dense(schedule, n_chunks):
    r = _run(DENSE_SCRIPT.format(schedule=schedule, n_chunks=n_chunks))
    assert "PP2_OK" in r.stdout and "PP4_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"


@pytest.mark.slow
@pytest.mark.parametrize("schedule,n_chunks", SCHEDULES)
def test_pipeline_matches_pp1_moe(schedule, n_chunks):
    r = _run(MOE_SCRIPT.format(schedule=schedule, n_chunks=n_chunks))
    assert "olmoe-1b-7b_MOE_OK" in r.stdout \
        and "deepseek-v3_MOE_OK" in r.stdout, \
        f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
