"""Recurrent blocks: RWKV6 "Finch" time-mix (data-dependent decay) and the
Mamba-flavoured head used by Hymba's parallel attn+SSM layers.

TPU adaptation (DESIGN.md §2): the recurrence runs as a `lax.scan` over
time with the per-head (d_head × state) outer-product state resident in
registers/VMEM — the TPU-native analogue of RWKV's fused CUDA kernel.  For
training with long sequences a chunked scan (block-parallel within chunks,
sequential across) keeps the activation trace O(s/chunk).

Parameter layout matches ``ModelSpec.ssm_params_per_layer`` exactly:
  r/k/v/g/o projections (5·h·d), decay LoRA (h·64 + 64·d) + per-channel u
  (d), 6 token-shift mus (6·h), optional depthwise conv (k·d).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import ModelSpec
from .layers import Params, dense_init

DECAY_RANK = 64


def ssm_init(key: jax.Array, spec: ModelSpec, dtype=jnp.bfloat16) -> Params:
    s = spec.ssm
    d = spec.h * s.ssm_expand
    ks = jax.random.split(key, 9)
    p = {
        "w_r": dense_init(ks[0], (spec.h, d), dtype),
        "w_k": dense_init(ks[1], (spec.h, d), dtype),
        "w_v": dense_init(ks[2], (spec.h, d), dtype),
        "w_g": dense_init(ks[3], (spec.h, d), dtype),
        "w_o": dense_init(ks[4], (d, spec.h), dtype),
        "decay_a": dense_init(ks[5], (spec.h, DECAY_RANK), dtype),
        "decay_b": dense_init(ks[6], (DECAY_RANK, d), dtype),
        "u": jnp.zeros((d,), dtype),                      # bonus (first-token)
        "mu": jnp.full((6, spec.h), 0.5, dtype),          # token-shift mixes
    }
    if s.conv_kernel:
        p["conv"] = dense_init(ks[7], (s.conv_kernel, d), dtype)
    return p


class SSMState(NamedTuple):
    """Per-layer recurrent state: (b, n_heads, head_dim, state_dim)."""
    s: jnp.ndarray
    x_prev: jnp.ndarray   # (b, h) last input (token shift)


def init_ssm_state(spec: ModelSpec, n_layers: int, b: int,
                   state_dtype=jnp.float32,
                   act_dtype=jnp.bfloat16) -> SSMState:
    ss = spec.ssm
    d = spec.h * ss.ssm_expand
    hd = d // ss.n_ssm_heads
    return SSMState(
        s=jnp.zeros((n_layers, b, ss.n_ssm_heads, hd, ss.state_dim),
                    state_dtype),
        x_prev=jnp.zeros((n_layers, b, spec.h), act_dtype))


def _shift(x: jnp.ndarray, x_prev: jnp.ndarray = None) -> jnp.ndarray:
    """RWKV token shift: previous timestep's input (zeros / carried state)."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev.astype(x.dtype)[:, None, :], x[:, :-1]],
                           axis=1)


def _mix(x, xs, mu):
    return x * mu + xs * (1.0 - mu)


def _project(p: Params, spec: ModelSpec, x: jnp.ndarray,
             x_prev: jnp.ndarray = None):
    """Token-shifted r/k/v/g/w projections reshaped into heads."""
    ss = spec.ssm
    d = spec.h * ss.ssm_expand
    hd = d // ss.n_ssm_heads
    b, s_len, _ = x.shape
    xs = _shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    r = _mix(x, xs, mu[0]) @ p["w_r"]
    k = _mix(x, xs, mu[1]) @ p["w_k"]
    v = _mix(x, xs, mu[2]) @ p["w_v"]
    g = _mix(x, xs, mu[3]) @ p["w_g"]
    # data-dependent decay (Finch): w_t = exp(-softplus(lora(x)))
    wlog = (_mix(x, xs, mu[4]) @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(-jax.nn.softplus(wlog.astype(jnp.float32)))   # (b,s,d) in (0,1)
    shp = (b, s_len, ss.n_ssm_heads, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            g.reshape(b, s_len, d), w.reshape(shp))


def rwkv6_forward(p: Params, spec: ModelSpec, x: jnp.ndarray
                  ) -> jnp.ndarray:
    """Training forward, full sequence.  x: (b, s, h) -> (b, s, h).

    State recurrence per head (wkv6):
      S_t = diag(w_t) S_{t-1} + k_t v_tᵀ        (d_head × state outer product)
      y_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    Here head_dim plays the paper's d_h role and state_dim = spec.ssm.state_dim.
    """
    ss = spec.ssm
    b, s_len, _ = x.shape
    r, k, v, g, w = _project(p, spec, x)
    hd = r.shape[-1]
    sd = ss.state_dim
    # fold value into state_dim-sized chunks: v (b,s,nh,hd) -> treat last dim
    # as (hd) keys against (sd)-dim values by slicing v to sd dims per head.
    # RWKV6 proper has hd == sd; where they differ we project v to sd.
    if hd != sd:
        v = v[..., :sd] if hd > sd else jnp.pad(v, ((0,0),)*3 + ((0, sd-hd),))
    u = p["u"].reshape(ss.n_ssm_heads, hd).astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp      # (b,nh,hd),(b,nh,hd),(b,nh,sd),(b,nh,hd)
        kv = jnp.einsum("bnk,bnv->bnkv", kt, vt)            # outer product
        yt = jnp.einsum("bnk,bnkv->bnv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, yt

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          w.transpose(1, 0, 2, 3))
    S0 = jnp.zeros((b, ss.n_ssm_heads, hd, sd), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s_len, ss.n_ssm_heads * sd)
    d = spec.h * ss.ssm_expand
    if y.shape[-1] != d:   # sd != hd: map back up to d
        y = jnp.pad(y, ((0, 0), (0, 0), (0, d - y.shape[-1])))
    y = y.astype(x.dtype) * jax.nn.silu(g)
    return y @ p["w_o"]


def rwkv6_decode(p: Params, spec: ModelSpec, x: jnp.ndarray,
                 state: jnp.ndarray, x_prev: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (b,1,h); state: (b,nh,hd,sd); x_prev: (b,h).
    O(1) in context length — why rwkv6/hymba run long_500k natively."""
    ss = spec.ssm
    b = x.shape[0]
    r, k, v, g, w = _project(p, spec, x, x_prev=x_prev)
    hd, sd = r.shape[-1], ss.state_dim
    if hd != sd:
        v = v[..., :sd] if hd > sd else jnp.pad(v, ((0,0),)*3 + ((0, sd-hd),))
    u = p["u"].reshape(ss.n_ssm_heads, hd).astype(jnp.float32)
    rt = r[:, 0].astype(jnp.float32)
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    wt = w[:, 0]
    kv = jnp.einsum("bnk,bnv->bnkv", kt, vt)
    yt = jnp.einsum("bnk,bnkv->bnv", rt, state + u[None, :, :, None] * kv)
    state = wt[..., None] * state + kv
    y = yt.reshape(b, 1, ss.n_ssm_heads * sd)
    d = spec.h * ss.ssm_expand
    if y.shape[-1] != d:
        y = jnp.pad(y, ((0, 0), (0, 0), (0, d - y.shape[-1])))
    y = y.astype(x.dtype) * jax.nn.silu(g)
    return y @ p["w_o"], state, x[:, 0]
