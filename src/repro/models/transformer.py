"""Transformer block composition and the scan-over-layers stack.

Layers are stored as *stacked* pytrees (leading axis = layer) and executed
with ``jax.lax.scan`` so XLA compiles one block body regardless of depth —
essential for the 80/94-layer dry-runs — with the activation-recomputation
policy applied to the scanned body (``jax.checkpoint``), exactly the knob
the paper's §5 analyses (AC None / Full / Selective).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import AttentionKind, FamilyKind, ModelSpec
from repro.core.parallel_config import RecomputePolicy
from . import attention as A
from . import backend as B
from . import mla as M
from . import moe as E
from . import ssm as S
from .layers import Params, mlp_apply, mlp_init, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    attn_impl: str = "naive"          # "naive" | "chunked" (flash-style)
    capacity_factor: float = 1.25
    recompute: RecomputePolicy = RecomputePolicy.NONE
    # Kernel backend for the hot ops (rmsnorm / attention / grouped_mlp):
    # "reference" (jnp) | "pallas" — resolved once per call site by
    # models.backend.resolve_backend.  "pallas" upgrades causal attention
    # to the flash kernel (attn_impl falls back loudly where the kernel's
    # contract doesn't hold — see backend.attention_fallbacks).
    backend: str = "reference"
    use_pallas: bool = False          # deprecated alias for backend="pallas"
    router_impl: str = "softmax"      # "softmax" | "sigmoid" (deepseek-v3)
    # scan (compile-once) vs python-loop (unrolled) over layers.  Unrolled is
    # used by the roofline cost probes: XLA's cost_analysis counts a while
    # body ONCE regardless of trip count, so per-layer costs must be probed
    # on unrolled modules and composed analytically (benchmarks/roofline.py).
    scan_layers: bool = True
    # "scatter" (GSPMD, default) | "a2a" (shard_map all-to-all EP dispatch —
    # the beyond-paper collective optimization; needs an active mesh with a
    # 'model' axis dividing n_routed).
    moe_impl: str = "scatter"
    # paper §5 partial recompute: fraction of each stack the policy covers
    # (the leading layers); the rest run AC-None.
    recompute_fraction: float = 1.0


def _remat(fn: Callable, policy: RecomputePolicy) -> Callable:
    if policy == RecomputePolicy.FULL:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == RecomputePolicy.SELECTIVE:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _norm(p, x, spec: ModelSpec, opts: Optional[ModelOptions] = None):
    gemma = spec.name.startswith("gemma")
    return B.rmsnorm(p, x, spec.norm_eps, gemma_style=gemma,
                     backend=B.resolve_backend(opts))


# ---------------------------------------------------------------------------
# Block init / apply (one layer; callers vmap/scan over stacks)
# ---------------------------------------------------------------------------

def block_init(key: jax.Array, spec: ModelSpec, is_moe_layer: bool,
               dtype=jnp.bfloat16, cross_attn: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": rmsnorm_init(spec.h, dtype),
                 "ln2": rmsnorm_init(spec.h, dtype)}
    if spec.attention == AttentionKind.MLA:
        p["attn"] = M.mla_init(ks[0], spec, dtype)
    elif spec.attention != AttentionKind.NONE:
        p["attn"] = A.gqa_init(ks[0], spec, dtype)
    if spec.ssm is not None:
        p["ssm"] = S.ssm_init(ks[1], spec, dtype)
        if spec.family == FamilyKind.HYBRID:
            p["merge_norm"] = rmsnorm_init(spec.h, dtype)
    if is_moe_layer:
        p["moe"] = E.moe_init(ks[2], spec, dtype)
    elif spec.h_ff:
        p["mlp"] = mlp_init(ks[3], spec, spec.h_ff, dtype)
    if cross_attn:
        p["ln_x"] = rmsnorm_init(spec.h, dtype)
        p["xattn"] = A.gqa_init(ks[4], spec, dtype)
    return p


def block_apply(p: Params, spec: ModelSpec, opts: ModelOptions,
                x: jnp.ndarray, positions: jnp.ndarray,
                is_moe_layer: bool,
                enc_out: Optional[jnp.ndarray] = None,
                window: Optional[int] = None,
                causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer layer; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(p["ln1"], x, spec, opts)
    backend = B.resolve_backend(opts)
    attn_impl = B.resolve_attn_impl(opts, causal=causal, window=window)

    mix = None
    if spec.attention == AttentionKind.MLA:
        mix = M.mla_forward(p["attn"], spec, h, positions, impl=attn_impl,
                            backend=backend)
    elif spec.attention != AttentionKind.NONE:
        if causal:
            mix = A.gqa_forward(p["attn"], spec, h, positions,
                                impl=attn_impl, window=window)
        else:  # encoder self-attention: bidirectional naive
            q, k, v = A._qkv(p["attn"], spec, h, positions)
            k = A._repeat_kv(k, spec.n_h // spec.n_kv)
            v = A._repeat_kv(v, spec.n_h // spec.n_kv)
            full = jnp.ones((h.shape[1], h.shape[1]), bool)
            ctx = A.naive_attention(q, k, v, full, spec.d_head ** -0.5)
            b, s = h.shape[:2]
            mix = ctx.reshape(b, s, spec.n_h * spec.d_head) @ p["attn"]["wo"]

    if spec.ssm is not None:
        ssm_out = S.rwkv6_forward(p["ssm"], spec, h)
        if spec.family == FamilyKind.HYBRID and mix is not None:
            # Hymba: parallel attention + SSM heads, normalised then averaged
            mix = 0.5 * (mix + _norm(p["merge_norm"], ssm_out, spec))
        else:
            mix = ssm_out
    x = x + mix

    if enc_out is not None:                      # decoder cross-attention
        hx = _norm(p["ln_x"], x, spec)
        q = (hx @ p["xattn"]["wq"]).reshape(
            hx.shape[0], hx.shape[1], spec.n_h, spec.d_head)
        k = (enc_out @ p["xattn"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], spec.n_kv, spec.d_head)
        v = (enc_out @ p["xattn"]["wv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], spec.n_kv, spec.d_head)
        k = A._repeat_kv(k, spec.n_h // spec.n_kv)
        v = A._repeat_kv(v, spec.n_h // spec.n_kv)
        full = jnp.ones((hx.shape[1], enc_out.shape[1]), bool)
        ctx = A.naive_attention(q, k, v, full, spec.d_head ** -0.5)
        x = x + ctx.reshape(hx.shape[0], hx.shape[1],
                            spec.n_h * spec.d_head) @ p["xattn"]["wo"]

    h2 = _norm(p["ln2"], x, spec, opts)
    if is_moe_layer:
        from repro.parallel.axes import current_mesh
        mesh = current_mesh()
        if opts.moe_impl == "a2a" and mesh is not None \
                and "model" in mesh.axis_names:
            from .moe_a2a import moe_forward_a2a
            out = moe_forward_a2a(p["moe"], spec, h2, mesh=mesh,
                                  capacity_factor=opts.capacity_factor,
                                  router_impl=opts.router_impl)
        else:
            out = E.moe_forward(p["moe"], spec, h2,
                                capacity_factor=opts.capacity_factor,
                                router_impl=opts.router_impl,
                                backend=backend)
        x = x + out.y
        aux = aux + out.aux_loss
    elif spec.h_ff:
        x = x + mlp_apply(p["mlp"], spec, h2)
    return x, aux


# ---------------------------------------------------------------------------
# Stacked layer groups
# ---------------------------------------------------------------------------

def stack_init(key: jax.Array, spec: ModelSpec, n: int, is_moe: bool,
               dtype=jnp.bfloat16, cross_attn: bool = False) -> Params:
    if n == 0:
        return {}
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, spec, is_moe, dtype,
                                         cross_attn=cross_attn))(keys)


def stack_apply(params: Params, spec: ModelSpec, opts: ModelOptions,
                x: jnp.ndarray, positions: jnp.ndarray, is_moe: bool,
                enc_out: Optional[jnp.ndarray] = None,
                window: Optional[int] = None,
                causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """scan over the stacked layer group with the remat policy applied."""
    if not params:
        return x, jnp.zeros((), jnp.float32)

    def body(carry, layer_p):
        xc, aux = carry
        xc, a = block_apply(layer_p, spec, opts, xc, positions, is_moe,
                            enc_out=enc_out, window=window, causal=causal)
        return (xc, aux + a), None

    n = jax.tree.leaves(params)[0].shape[0]
    n_rc = int(round(opts.recompute_fraction * n)) \
        if opts.recompute != RecomputePolicy.NONE else n
    body_rc = _remat(body, opts.recompute)
    carry = (x, jnp.zeros((), jnp.float32))
    if opts.scan_layers and (n_rc in (0, n)):
        (x, aux), _ = jax.lax.scan(body_rc if n_rc else body, carry, params)
    elif opts.scan_layers:
        # partial recompute: two scans — first n_rc layers remat, rest not
        head = jax.tree.map(lambda a: a[:n_rc], params)
        tail = jax.tree.map(lambda a: a[n_rc:], params)
        carry, _ = jax.lax.scan(body_rc, carry, head)
        (x, aux), _ = jax.lax.scan(body, carry, tail)
    else:
        for i in range(n):
            layer_p = jax.tree.map(lambda a: a[i], params)
            carry, _ = (body_rc if i < n_rc else body)(carry, layer_p)
        x, aux = carry
    return x, aux
