"""Model substrate: composable pure-JAX definitions for all assigned
architecture families (dense GQA/MQA, MLA+MoE, SSM, hybrid, enc-dec, VLM)."""

from .model import Model, build_model

__all__ = ["Model", "build_model"]
