"""Multi-Head Latent Attention (DeepSeek-v2/v3), paper §1/§3.2/§5.1.

Training path mirrors Figure 2's activation pattern: query tower
(W^DQ → norm → W^UQ/W^QR), latent KV (W^DKV → norm → W^UK/W^UV), shared
rope key W^KR, softmax over concat(nope, rope) dims, W^O out.

Decode path caches the *compressed* latent (d_c + d_hr per token — the MLA
memory advantage the paper's Table 2 geometry implies) and absorbs W^UK into
the query so scores contract in the 512-dim latent space.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import ModelSpec
from .layers import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -2.0 ** 30


def mla_init(key: jax.Array, spec: ModelSpec, dtype=jnp.bfloat16) -> Params:
    m = spec.mla
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (spec.h, m.d_cq), dtype),
        "w_uq": dense_init(ks[1], (m.d_cq, spec.n_h * m.d_h), dtype),
        "w_qr": dense_init(ks[2], (m.d_cq, spec.n_h * m.d_hr), dtype),
        "w_dkv": dense_init(ks[3], (spec.h, m.d_c), dtype),
        "w_uk": dense_init(ks[4], (m.d_c, spec.n_h * m.d_h), dtype),
        "w_uv": dense_init(ks[5], (m.d_c, spec.n_h * m.d_v), dtype),
        "w_kr": dense_init(ks[6], (spec.h, m.d_hr), dtype),
        "w_o": dense_init(ks[7], (spec.n_h * m.d_v, spec.h), dtype),
        "q_norm": rmsnorm_init(m.d_cq, dtype),
        "kv_norm": rmsnorm_init(m.d_c, dtype),
    }


def _towers(p: Params, spec: ModelSpec, x: jnp.ndarray,
            positions: jnp.ndarray, tpf=None, backend: str = "reference"):
    """Shared by train fwd and prefill: returns q (nope‖rope), k (nope‖rope), v.

    ``tpf`` (optional) is the executor's tensor-parallel entry operator
    (``parallel.tp.copy_to_tp``): the down-projections W^DQ/W^DKV/W^KR are
    replicated across TP (paper §3.2) and computed redundantly on every
    shard, so the compressed latents — the points where the replicated
    towers fan out into head-sharded up-projections — are where the
    backward pass must all-reduce.

    Under the executor's sequence parallelism the caller gathers the
    seq-sharded block input *before* these towers
    (``models.pipeline._slot_apply``): the replicated latent towers always
    consume the full-sequence view, so cq/c_kv stay ``2bs(d_cq+d_c)`` per
    shard — the terms the paper's Table 10 leaves undivided by sp — but
    ``tpf`` must then be ``None``: the entry ğ's reduce-scatter backward
    already sums the per-shard partial cotangents, so keeping
    ``copy_to_tp``'s psum-bwd here would double-count (tp× gradients on
    the whole attention branch).  The tower weight grads are instead
    completed by the executor's post-loop 'model'-axis psum.
    """
    from . import backend as B
    m = spec.mla
    b, s, _ = x.shape
    tpf = tpf if tpf is not None else (lambda t: t)
    cq = tpf(B.rmsnorm(p["q_norm"], x @ p["w_dq"], spec.norm_eps,
                       backend=backend))
    q_nope = (cq @ p["w_uq"]).reshape(b, s, spec.n_h, m.d_h)
    q_rope = apply_rope((cq @ p["w_qr"]).reshape(b, s, spec.n_h, m.d_hr),
                        positions, spec.rope_theta)
    c_kv = tpf(B.rmsnorm(p["kv_norm"], x @ p["w_dkv"], spec.norm_eps,
                         backend=backend))
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, spec.n_h, m.d_h)
    k_rope = apply_rope((x @ p["w_kr"]).reshape(b, s, 1, m.d_hr),
                        positions, spec.rope_theta)
    k_rope = jnp.broadcast_to(tpf(k_rope), (b, s, spec.n_h, m.d_hr))
    v = (c_kv @ p["w_uv"]).reshape(b, s, spec.n_h, m.d_v)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q, k, v


def mla_forward(p: Params, spec: ModelSpec, x: jnp.ndarray,
                positions: jnp.ndarray, *, impl: str = "naive",
                tpf=None, backend: str = "reference") -> jnp.ndarray:
    from . import backend as B
    m = spec.mla
    b, s, _ = x.shape
    q, k, v = _towers(p, spec, x, positions, tpf, backend=backend)
    scale = (m.d_h + m.d_hr) ** -0.5
    ctx = B.mla_attention(q, k, v, scale=scale, impl=impl)
    return ctx.reshape(b, s, spec.n_h * m.d_v) @ p["w_o"]


# ---------------------------------------------------------------------------
# Decode with compressed-latent cache
# ---------------------------------------------------------------------------

def init_mla_cache(spec: ModelSpec, n_layers: int, b: int, cache_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    m = spec.mla
    return {
        "c_kv": jnp.zeros((n_layers, b, cache_len, m.d_c), dtype),
        "k_rope": jnp.zeros((n_layers, b, cache_len, m.d_hr), dtype),
    }


def mla_decode(p: Params, spec: ModelSpec, x: jnp.ndarray,
               c_cache: jnp.ndarray, r_cache: jnp.ndarray,
               index: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token MLA decode with latent cache.

    x: (b, 1, h);  c_cache: (b, C, d_c);  r_cache: (b, C, d_hr);  index: ().
    Scores via weight absorption: q_eff = W^UKᵀ q_nope contracts against the
    cached latent directly; values reconstructed as (probs @ c) W^UV.
    """
    m = spec.mla
    b = x.shape[0]
    cache_len = c_cache.shape[1]
    pos = jnp.full((b, 1), index, jnp.int32)

    cq = rmsnorm(p["q_norm"], x @ p["w_dq"], spec.norm_eps)
    q_nope = (cq @ p["w_uq"]).reshape(b, 1, spec.n_h, m.d_h)
    q_rope = apply_rope((cq @ p["w_qr"]).reshape(b, 1, spec.n_h, m.d_hr),
                        pos, spec.rope_theta)

    c_new = rmsnorm(p["kv_norm"], x @ p["w_dkv"], spec.norm_eps)   # (b,1,d_c)
    r_new = apply_rope((x @ p["w_kr"]).reshape(b, 1, 1, m.d_hr),
                       pos, spec.rope_theta).reshape(b, 1, m.d_hr)
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, index, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(r_cache, r_new, index, axis=1)

    # absorb W^UK: (b,1,nh,d_h) x (d_c, nh*d_h) -> (b,1,nh,d_c)
    w_uk = p["w_uk"].reshape(m.d_c, spec.n_h, m.d_h)
    q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)
    s_nope = jnp.einsum("bqhc,bkc->bhqk", q_lat, c_cache)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, r_cache)
    scale = (m.d_h + m.d_hr) ** -0.5
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    valid = jnp.arange(cache_len) <= index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    ctx_lat = jnp.einsum("bhqk,bkc->bqhc", probs, c_cache)         # (b,1,nh,d_c)
    w_uv = p["w_uv"].reshape(m.d_c, spec.n_h, m.d_v)
    ctx = jnp.einsum("bqhc,chd->bqhd", ctx_lat, w_uv)
    out = ctx.reshape(b, 1, spec.n_h * m.d_v) @ p["w_o"]
    return out, c_cache, r_cache
