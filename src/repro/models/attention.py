"""Standard attention family: MHA / GQA / MQA, causal, RoPE, KV cache,
sliding-window variant (enables long-context decode for dense archs).

Two score paths:
* ``naive``   — materialises (b, n_h, s, s) scores; mirrors the paper's
  activation accounting (the 5·b·n_h·s² term).
* ``chunked`` — lax.scan online-softmax over KV blocks (flash-style, O(s)
  activation memory); the beyond-paper memory optimization, and the jnp
  twin of the Pallas kernel in ``repro.kernels``.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import ModelSpec
from .layers import Params, apply_rope, dense_init

NEG_INF = -2.0 ** 30


def gqa_init(key: jax.Array, spec: ModelSpec, dtype=jnp.bfloat16) -> Params:
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    d, nh, nkv = spec.d_head, spec.n_h, spec.n_kv
    p = {
        "wq": dense_init(kq, (spec.h, nh * d), dtype),
        "wk": dense_init(kk, (spec.h, nkv * d), dtype),
        "wv": dense_init(kv, (spec.h, nkv * d), dtype),
        "wo": dense_init(ko, (nh * d, spec.h), dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((nh * d,), dtype)
        p["bk"] = jnp.zeros((nkv * d,), dtype)
        p["bv"] = jnp.zeros((nkv * d,), dtype)
    return p


def _qkv(p: Params, spec: ModelSpec, x: jnp.ndarray,
         positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    d, nh, nkv = spec.d_head, spec.n_h, spec.n_kv
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, d)
    k = k.reshape(b, s, nkv, d)
    v = v.reshape(b, s, nkv, d)
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, nkv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, nkv, n_rep, d)) \
        .reshape(b, s, nkv * n_rep, d)


def causal_mask(s: int, window: Optional[int] = None) -> jnp.ndarray:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    return m


def naive_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    mask: jnp.ndarray, scale: float) -> jnp.ndarray:
    """q:(b,s,nh,d) k/v:(b,s,nh,d) mask:(s,s) -> (b,s,nh,d)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      scale: float, block: int = 512,
                      window: Optional[int] = None) -> jnp.ndarray:
    """Online-softmax causal attention, O(s·block) live memory.

    Scans over KV blocks carrying (m, l, acc) — the flash-attention
    recurrence — so the s×s score matrix never materialises.
    """
    b, s, nh, d = q.shape
    dv = v.shape[-1]                      # v head dim may differ (MLA)
    nb = -(-s // block)
    pad = nb * block - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block, nh, d)
    vb = v.reshape(b, nb, block, nh, dv)
    q32 = (q * scale).astype(jnp.float32)
    qpos = jnp.arange(s)

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, blk_idx = inputs
        kpos = blk_idx * block + jnp.arange(block)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q32, kblk.astype(jnp.float32))
        valid = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < s)
        if window is not None:
            valid &= kpos[None, :] > qpos[:, None] - window
        sc = jnp.where(valid[None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nh, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nh, s), jnp.float32)
    a0 = jnp.zeros((b, nh, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def gqa_forward(p: Params, spec: ModelSpec, x: jnp.ndarray,
                positions: jnp.ndarray, *, impl: str = "naive",
                window: Optional[int] = None) -> jnp.ndarray:
    from . import backend as B
    q, k, v = _qkv(p, spec, x, positions)
    n_rep = spec.n_h // spec.n_kv
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = spec.d_head ** -0.5
    ctx = B.attention(q, k, v, scale=scale, impl=impl, window=window)
    b, s = x.shape[:2]
    return ctx.reshape(b, s, spec.n_h * spec.d_head) @ p["wo"]


# ---------------------------------------------------------------------------
# Decode path (KV cache; ring buffer when sliding_window is set)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray          # (b, cache_len, n_kv, d)
    v: jnp.ndarray
    index: jnp.ndarray      # () int32 — next absolute position


def init_kv_cache(spec: ModelSpec, n_layers: int, b: int, cache_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, b, cache_len, spec.n_kv, spec.d_head)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   index=jnp.zeros((), jnp.int32))


def gqa_decode(p: Params, spec: ModelSpec, x: jnp.ndarray,
               k_cache: jnp.ndarray, v_cache: jnp.ndarray,
               index: jnp.ndarray, *, window: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (b, 1, h); caches: (b, C, n_kv, d); index: ().

    With ``window`` set, C == window and writes wrap (ring buffer) — the
    sliding-window variant that makes long_500k feasible for dense archs.
    Returns (out, new_k_cache, new_v_cache).
    """
    b = x.shape[0]
    cache_len = k_cache.shape[1]
    pos = jnp.full((b, 1), index, jnp.int32)
    q, k_new, v_new = _qkv(p, spec, x, pos)
    slot = index % cache_len if window is not None else index
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)

    n_rep = spec.n_h // spec.n_kv
    kk = _repeat_kv(k_cache, n_rep)
    vv = _repeat_kv(v_cache, n_rep)
    scale = spec.d_head ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    kpos = jnp.arange(cache_len)
    if window is not None:
        valid = (kpos[None, :] <= index) | jnp.full((1, cache_len), True)
        # ring buffer: every slot written within the last `window` steps is
        # valid once index >= cache_len; before that only slots <= index.
        valid = kpos <= jnp.minimum(index, cache_len - 1)
        wrapped = index >= cache_len
        valid = jnp.where(wrapped, jnp.ones_like(valid), valid)
    else:
        valid = kpos <= index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = ctx.reshape(b, 1, spec.n_h * spec.d_head) @ p["wo"]
    return out, k_cache, v_cache
