"""The public model API: build_model(spec) → Model.

Model bundles: parameter init (stacked layer pytrees), training forward +
loss (next-token CE + MoE aux), and single-token decode with the
family-appropriate cache (GQA KV / MLA latent / SSM state / enc-dec cross).
All functions are pure and pjit-compatible; sharding is expressed through
logical-axis annotations (repro.parallel.axes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import AttentionKind, FamilyKind, ModelSpec
from repro.parallel.axes import logical_constraint
from . import attention as A
from . import mla as M
from . import ssm as S
from .layers import (Params, embed_apply, embed_init, head_apply, head_init,
                     rmsnorm, rmsnorm_init)
from .transformer import ModelOptions, block_apply, stack_apply, stack_init

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    spec: ModelSpec
    opts: ModelOptions

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, rng: jax.Array, dtype=jnp.bfloat16) -> PyTree:
        spec = self.spec
        k_emb, k_dense, k_moe, k_head, k_enc = jax.random.split(rng, 5)
        n_moe = spec.n_moe_layers()
        n_dense = spec.n_layers - n_moe
        cross = spec.encoder is not None
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, spec.vocab, spec.h, dtype),
            "dense_layers": stack_init(k_dense, spec, n_dense, False, dtype,
                                       cross_attn=cross),
            "moe_layers": stack_init(k_moe, spec, n_moe, True, dtype),
            "final_norm": rmsnorm_init(spec.h, dtype),
        }
        if not spec.tie_embeddings:
            params["head"] = head_init(k_head, spec.h, spec.vocab, dtype)
        if spec.encoder is not None:
            ks = jax.random.split(k_enc, 2)
            params["encoder"] = {
                "layers": stack_init(ks[0], spec, spec.encoder.n_layers,
                                     False, dtype),
                "final_norm": rmsnorm_init(spec.h, dtype),
            }
        return params

    def abstract_params(self, dtype=jnp.bfloat16) -> PyTree:
        """Shape/dtype skeleton without allocation (dry-run path)."""
        return jax.eval_shape(lambda k: self.init(k, dtype),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))

    # ------------------------------------------------------------------
    # training forward / loss
    # ------------------------------------------------------------------

    def _backbone(self, params: PyTree, x: jnp.ndarray,
                  positions: jnp.ndarray,
                  enc_out: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        spec = self.spec
        x = logical_constraint(x, ("batch", "seq", "embed"))
        window = spec.sliding_window
        x, aux1 = stack_apply(params["dense_layers"], spec, self.opts, x,
                              positions, False, enc_out=enc_out, window=window)
        x, aux2 = stack_apply(params["moe_layers"], spec, self.opts, x,
                              positions, True, window=window)
        return x, aux1 + aux2

    def forward(self, params: PyTree, batch: Dict[str, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """batch: tokens (b,s) int32 [+ vision_embeds | audio_embeds].
        Returns (logits (b,s,v) bf16, aux_loss)."""
        spec = self.spec
        tokens = batch["tokens"]
        b, s_len = tokens.shape
        x = embed_apply(params["embed"], tokens,
                        scale_by_dim=spec.name.startswith("gemma"), h=spec.h)

        if spec.family == FamilyKind.VLM and "vision_embeds" in batch:
            # stubbed ViT frontend: patch embeddings occupy the first
            # n_patch positions of the interleaved sequence (DESIGN.md §4)
            ve = batch["vision_embeds"].astype(x.dtype)
            n_p = ve.shape[1]
            x = x.at[:, :n_p, :].add(ve)

        positions = jnp.broadcast_to(jnp.arange(s_len)[None], (b, s_len))

        enc_out = None
        if spec.encoder is not None:
            enc_out = self._encode(params, batch["audio_embeds"])

        x, aux = self._backbone(params, x, positions, enc_out=enc_out)
        x = rmsnorm(params["final_norm"], x, spec.norm_eps,
                    gemma_style=spec.name.startswith("gemma"))
        if spec.tie_embeddings:
            logits = x @ params["embed"]["w"].T
        else:
            logits = x @ params["head"]["w"]
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        return logits, aux

    def _encode(self, params: PyTree, audio_embeds: jnp.ndarray) -> jnp.ndarray:
        """Whisper encoder over stubbed mel/conv frame embeddings."""
        spec = self.spec
        b, s_len, _ = audio_embeds.shape
        pos = jnp.broadcast_to(jnp.arange(s_len)[None], (b, s_len))
        x = audio_embeds.astype(jnp.bfloat16)
        x, _ = stack_apply(params["encoder"]["layers"], spec, self.opts, x,
                           pos, False, causal=False)
        return rmsnorm(params["encoder"]["final_norm"], x, spec.norm_eps)

    def loss(self, params: PyTree, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
        if mask.shape == tokens.shape:
            mask = mask[:, 1:]
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "loss": total}

    # ------------------------------------------------------------------
    # decode (serving)
    # ------------------------------------------------------------------

    def init_cache(self, b: int, cache_len: int,
                   enc_out: Optional[jnp.ndarray] = None,
                   dtype=jnp.bfloat16) -> PyTree:
        spec = self.spec
        n_moe = spec.n_moe_layers()
        n_dense = spec.n_layers - n_moe
        window = spec.sliding_window
        eff = min(cache_len, window) if window else cache_len
        cache: Dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
        if spec.attention == AttentionKind.MLA:
            m = spec.mla
            cache["mla"] = {
                "c": jnp.zeros((spec.n_layers, b, eff, m.d_c), dtype),
                "r": jnp.zeros((spec.n_layers, b, eff, m.d_hr), dtype)}
        elif spec.attention != AttentionKind.NONE:
            cache["kv"] = {
                "k": jnp.zeros((spec.n_layers, b, eff, spec.n_kv,
                                spec.d_head), dtype),
                "v": jnp.zeros((spec.n_layers, b, eff, spec.n_kv,
                                spec.d_head), dtype)}
        if spec.ssm is not None:
            st = S.init_ssm_state(spec, spec.n_layers, b)
            cache["ssm"] = {"s": st.s, "x_prev": st.x_prev}
        if spec.encoder is not None:
            assert enc_out is not None, "enc-dec decode needs encoder output"
            cache["enc_out"] = enc_out
        return cache

    def decode_step(self, params: PyTree, cache: PyTree,
                    tokens: jnp.ndarray) -> Tuple[jnp.ndarray, PyTree]:
        """One token for every sequence: tokens (b, 1) → (logits (b,1,v), cache)."""
        spec, opts = self.spec, self.opts
        b = tokens.shape[0]
        idx = cache["index"]
        x = embed_apply(params["embed"], tokens,
                        scale_by_dim=spec.name.startswith("gemma"), h=spec.h)
        x = logical_constraint(x, ("batch", None, "embed"))
        enc_out = cache.get("enc_out")

        n_dense = spec.n_layers - spec.n_moe_layers()

        def layer_decode(x, layer_p, layer_cache, is_moe):
            aux = {}
            h = rmsnorm(layer_p["ln1"], x, spec.norm_eps,
                        gemma_style=spec.name.startswith("gemma"))
            mix = None
            new_cache = dict(layer_cache)
            if spec.attention == AttentionKind.MLA:
                mix, c, r = M.mla_decode(layer_p["attn"], spec, h,
                                         layer_cache["c"], layer_cache["r"], idx)
                new_cache.update(c=c, r=r)
            elif spec.attention != AttentionKind.NONE:
                mix, k, v = A.gqa_decode(layer_p["attn"], spec, h,
                                         layer_cache["k"], layer_cache["v"],
                                         idx, window=spec.sliding_window)
                new_cache.update(k=k, v=v)
            if spec.ssm is not None:
                so, s_new, xp = S.rwkv6_decode(layer_p["ssm"], spec, h,
                                               layer_cache["s"],
                                               layer_cache["x_prev"])
                new_cache.update(s=s_new, x_prev=xp)
                if spec.family == FamilyKind.HYBRID and mix is not None:
                    mn = rmsnorm(layer_p["merge_norm"], so, spec.norm_eps)
                    mix = 0.5 * (mix + mn)
                else:
                    mix = so
            x = x + mix
            if enc_out is not None:
                hx = rmsnorm(layer_p["ln_x"], x, spec.norm_eps)
                q = (hx @ layer_p["xattn"]["wq"]).reshape(b, 1, spec.n_h,
                                                          spec.d_head)
                ek = layer_cache["enc_k"]
                ev = layer_cache["enc_v"]
                sc = jnp.einsum("bqhd,bkhd->bhqk", q, ek).astype(jnp.float32)
                pr = jax.nn.softmax(sc * spec.d_head ** -0.5, -1).astype(x.dtype)
                ctx = jnp.einsum("bhqk,bkhd->bqhd", pr, ev)
                x = x + ctx.reshape(b, 1, spec.n_h * spec.d_head) \
                    @ layer_p["xattn"]["wo"]
            h2 = rmsnorm(layer_p["ln2"], x, spec.norm_eps,
                         gemma_style=spec.name.startswith("gemma"))
            if is_moe:
                from .moe import moe_forward
                out = moe_forward(layer_p["moe"], spec, h2,
                                  capacity_factor=opts.capacity_factor,
                                  router_impl=opts.router_impl)
                x = x + out.y
            elif spec.h_ff:
                from .layers import mlp_apply
                x = x + mlp_apply(layer_p["mlp"], spec, h2)
            return x, new_cache

        def scan_group(x, group_params, group_cache, is_moe):
            if not group_params:
                return x, group_cache

            def body(xc, inp):
                lp, lc = inp
                xc, nc = layer_decode(xc, lp, lc, is_moe)
                return xc, nc

            if opts.scan_layers:
                x, new_cache = jax.lax.scan(body, x,
                                            (group_params, group_cache))
            else:
                n = jax.tree.leaves(group_params)[0].shape[0]
                outs = []
                for i in range(n):
                    lp = jax.tree.map(lambda a: a[i], group_params)
                    lc = jax.tree.map(lambda a: a[i], group_cache)
                    x, nc = layer_decode(x, lp, lc, is_moe)
                    outs.append(nc)
                new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            return x, new_cache

        # split stacked caches between the dense and moe layer groups
        def split_cache(tree, lo, hi):
            return jax.tree.map(lambda a: a[lo:hi], tree)

        per_layer_cache: Dict[str, Any] = {}
        if "mla" in cache:
            per_layer_cache.update(c=cache["mla"]["c"], r=cache["mla"]["r"])
        if "kv" in cache:
            per_layer_cache.update(k=cache["kv"]["k"], v=cache["kv"]["v"])
        if "ssm" in cache:
            per_layer_cache.update(s=cache["ssm"]["s"],
                                   x_prev=cache["ssm"]["x_prev"])
        if enc_out is not None:
            # precomputed cross K/V would normally live in the cache; compute
            # per step from enc_out to keep the cache small (enc ctx is short)
            dense_p = params["dense_layers"]
            ek = jnp.einsum("bsh,lhd->lbsd", enc_out, dense_p["xattn"]["wk"]) \
                .reshape(spec.n_layers, b, -1, spec.n_kv, spec.d_head)
            ev = jnp.einsum("bsh,lhd->lbsd", enc_out, dense_p["xattn"]["wv"]) \
                .reshape(spec.n_layers, b, -1, spec.n_kv, spec.d_head)
            ek = A._repeat_kv(ek.reshape(spec.n_layers * b, -1, spec.n_kv,
                                         spec.d_head),
                              spec.n_h // spec.n_kv).reshape(
                spec.n_layers, b, -1, spec.n_h, spec.d_head)
            ev = A._repeat_kv(ev.reshape(spec.n_layers * b, -1, spec.n_kv,
                                         spec.d_head),
                              spec.n_h // spec.n_kv).reshape(
                spec.n_layers, b, -1, spec.n_h, spec.d_head)
            per_layer_cache.update(enc_k=ek, enc_v=ev)

        dense_cache = split_cache(per_layer_cache, 0, n_dense)
        moe_cache = split_cache(per_layer_cache, n_dense, spec.n_layers)

        x, new_dense_cache = scan_group(x, params["dense_layers"],
                                        dense_cache, False)
        x, new_moe_cache = scan_group(x, params["moe_layers"], moe_cache, True)

        x = rmsnorm(params["final_norm"], x, spec.norm_eps,
                    gemma_style=spec.name.startswith("gemma"))
        if spec.tie_embeddings:
            logits = x @ params["embed"]["w"].T
        else:
            logits = x @ params["head"]["w"]
        logits = logical_constraint(logits, ("batch", None, "vocab"))

        # stitch caches back together
        def join(a, b_):
            if a is None:
                return b_
            if b_ is None:
                return a
            return jnp.concatenate([a, b_], axis=0)

        new_cache = dict(cache)
        new_cache["index"] = idx + 1

        def merged(field):
            d = new_dense_cache.get(field) if new_dense_cache else None
            m_ = new_moe_cache.get(field) if new_moe_cache else None
            return join(d, m_)

        if "mla" in cache:
            new_cache["mla"] = {"c": merged("c"), "r": merged("r")}
        if "kv" in cache:
            new_cache["kv"] = {"k": merged("k"), "v": merged("v")}
        if "ssm" in cache:
            new_cache["ssm"] = {"s": merged("s"), "x_prev": merged("x_prev")}
        return logits, new_cache


def build_model(spec: ModelSpec, opts: Optional[ModelOptions] = None) -> Model:
    return Model(spec=spec, opts=opts or ModelOptions())
