"""Expert-parallel MoE dispatch via explicit all-to-all (shard_map).

The default ``moe_forward`` (moe.py) scatters tokens into an (E, C, h)
buffer sharded only on E.  Under GSPMD that lowers to an ALL-GATHER of the
full (T·K, h) assignment tensor onto every expert shard — measured at
~6.4 TB/device/step for qwen3-moe train_4k (127 s of ICI time; EXPERIMENTS
§Perf hillclimb 1).  The paper's EP (§3.3) assumes Megatron/DeepSpeed-style
token exchange: each device sends only the tokens its peers' experts need —
an all-to-all.

This module is that exchange, written with jax.shard_map + lax.all_to_all:

  1. tokens sharded (batch over data/pod, seq over model) — every device
     owns T_loc tokens exactly once;
  2. route locally, bucket assignments by destination expert shard
     (dest = expert // E_local), capacity C_send per destination;
  3. all_to_all over 'model' swaps the (M, C_send, h) send buffer;
  4. local grouped expert FFN on the received rows;
  5. all_to_all back, combine with the locally-kept gates.

Collective volume per device per layer ≈ 2 · T_loc·K·h·2 B (send + return)
versus the all-gather's T_global·K·h·2 B — a (world/2·)× reduction.
Differentiable end-to-end (all_to_all transposes to all_to_all).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.notation import ModelSpec
from repro.parallel.compat import shard_map
from .layers import mlp_apply
from .moe import (MoEOutput, _positions_in_expert, _route,
                  _send_eid_buffer)


def local_expert_capacity(tk: int, e_loc: int, capacity_factor: float) -> int:
    """Per-expert row capacity of the post-exchange ``(E_loc, C, h)``
    buffer: each device receives (in balanced expectation) its row's
    ``tk = t_loc·K`` assignments back, spread over its ``E_loc`` local
    experts — ``capacity_factor`` applied ONCE.  This matches the
    estimator's ``E_token·cf`` term (``core.activations.moe_activation
    _bytes``); deriving it from the already-cf-scaled ``c_send`` instead
    double-applied the factor (a ~cf× oversized buffer)."""
    return max(1, int(round(tk / max(e_loc, 1) * capacity_factor)))


def moe_forward_a2a(params, spec: ModelSpec, x: jnp.ndarray, *,
                    mesh, capacity_factor: float = 1.25,
                    router_impl: str = "softmax") -> MoEOutput:
    """x: (b, s, h) -> (b, s, h) with EP all-to-all dispatch.

    Requires a mesh with a 'model' axis whose size divides n_routed, and
    b divisible by the data axes (s by the model axis).
    """
    e = spec.moe
    axis_names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    M = mesh.shape["model"]
    E_loc = e.n_routed // M
    assert E_loc * M == e.n_routed

    lparams = {
        "router": params["router"],
        "we_gate": params["we_gate"],
        "we_up": params["we_up"],
        "we_down": params["we_down"],
    }

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=({"router": P(None, None),
                   "we_gate": P("model", None, None),
                   "we_up": P("model", None, None),
                   "we_down": P("model", None, None)},
                  P(data_axes, "model", None)),
        out_specs=(P(data_axes, "model", None), P(),
                   P(data_axes, "model", None)))
    def dispatch(lp, xs):
        b_loc, s_loc, h = xs.shape
        t_loc = b_loc * s_loc
        xt = xs.reshape(t_loc, h)
        probs, gates, eids = _route(lp["router"], spec, xt, router_impl)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eids, e.n_routed,
                                     dtype=jnp.float32).sum(1), axis=0) \
            / e.n_active
        aux = e.n_routed * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, data_axes + ("model",))

        tk = t_loc * e.n_active
        flat_eids = eids.reshape(tk)
        flat_gates = (gates.reshape(tk)).astype(xs.dtype)
        dest = flat_eids // E_loc
        local_eid = flat_eids % E_loc

        c_send = max(1, int(round(tk / M * capacity_factor)))
        pos_d, _ = _positions_in_expert(dest, M)
        keep_s = pos_d < c_send
        pos_dc = jnp.minimum(pos_d, c_send - 1)

        src = jnp.repeat(xt, e.n_active, axis=0) \
            * keep_s[:, None].astype(xs.dtype)
        send = jnp.zeros((M, c_send, h), xs.dtype).at[dest, pos_dc].add(src)
        # unclamped pos_d: overflow writes drop instead of colliding with
        # slot c_send-1's real expert id (see moe._send_eid_buffer)
        send_eid = _send_eid_buffer(dest, pos_d, local_eid, M, c_send, E_loc)

        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, "model", split_axis=0,
                                      concat_axis=0, tiled=False)

        rows = recv.reshape(M * c_send, h)
        row_eid = recv_eid.reshape(M * c_send)
        pos_e, _ = _positions_in_expert(row_eid, E_loc + 1)
        c_loc = local_expert_capacity(tk, E_loc, capacity_factor)
        keep_e = (pos_e < c_loc) & (row_eid < E_loc)
        pos_e = jnp.minimum(pos_e, c_loc - 1)
        eid_c = jnp.minimum(row_eid, E_loc - 1)
        buf = jnp.zeros((E_loc, c_loc, h), xs.dtype) \
            .at[eid_c, pos_e].add(rows * keep_e[:, None].astype(xs.dtype))

        a = jax.nn.silu(jnp.einsum("ech,ehf->ecf", buf, lp["we_gate"]))
        a = a * jnp.einsum("ech,ehf->ecf", buf, lp["we_up"])
        out_buf = jnp.einsum("ecf,efh->ech", a, lp["we_down"])

        back = (out_buf[eid_c, pos_e] * keep_e[:, None].astype(xs.dtype)) \
            .reshape(M, c_send, h)
        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=False)

        y_pairs = ret[dest, pos_dc] * (flat_gates
                                      * keep_s.astype(xs.dtype))[:, None]
        y = y_pairs.reshape(t_loc, e.n_active, h).sum(axis=1)
        # probs reshaped to the (b_loc, s_loc, E) layout so the out_spec
        # reassembles the *global* (b, s, E) tensor — routing is per-token,
        # so the assembled probs are exactly the scatter path's
        return (y.reshape(b_loc, s_loc, h), aux,
                probs.reshape(b_loc, s_loc, e.n_routed))

    y, aux, probs = dispatch(lparams, x)
    if e.n_shared:
        b, s, h = x.shape
        y = y + mlp_apply(params["shared"], spec, x.reshape(-1, h)) \
            .reshape(b, s, h)
    return MoEOutput(y=y, aux_loss=aux,
                     router_probs=probs.reshape(-1, e.n_routed))
