"""Mixture-of-experts layer (paper §1.1/§3.3/§5.2).

Capacity-based token dispatch, built from sort/scatter primitives so the
per-device expert buffer is (E, C, h) — shardable on the expert axis (EP over
the mesh's ``model`` axis) — rather than the (T, E, C) one-hot einsum of
GShard, which is infeasible at long sequence lengths.

Matches the paper's accounting: balanced load gives E_token = b·s·N_r/N
tokens per expert (capacity_factor=1.0 reproduces §5.2 exactly; default 1.25
gives headroom like production routers).  Shared experts process every token
and are replicated across EP ranks (paper §3.3).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import ModelSpec
from .layers import Params, dense_init, mlp_apply, mlp_init


class MoEOutput(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray       # load-balance auxiliary loss
    router_probs: jnp.ndarray   # (T, E) fp32 (paper keeps 4bsN router acts)


def moe_init(key: jax.Array, spec: ModelSpec, dtype=jnp.bfloat16) -> Params:
    e = spec.moe
    kr, ke, ks = jax.random.split(key, 3)
    kg, ku, kd = jax.random.split(ke, 3)
    E, h, f = e.n_routed, spec.h, e.d_ff_expert
    p = {
        "router": dense_init(kr, (h, E), jnp.float32, scale=h ** -0.5),
        # stacked expert weights: leading dim = expert (EP-sharded)
        "we_gate": dense_init(kg, (E, h, f), dtype),
        "we_up": dense_init(ku, (E, h, f), dtype),
        "we_down": dense_init(kd, (E, f, h), dtype),
    }
    if e.n_shared:
        p["shared"] = mlp_init(ks, spec, f * e.n_shared, dtype)
    return p


def _positions_in_expert(eids: jnp.ndarray, n_expert: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For flat expert assignments (TK,), compute each assignment's rank
    within its expert and the per-expert totals.

    Sort-based: O(TK log TK) compares.  (A (TK, E) one-hot cumsum is the
    obvious alternative but XLA lowers it to a reduce-window that both
    costs and *counts* O(TK²·E) — it dominated the roofline compute term
    100× over the expert matmuls before this change; see EXPERIMENTS.md
    §Perf iteration log.)"""
    tk = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_eids = eids[order]
    counts = jnp.zeros((n_expert,), jnp.int32).at[eids].add(1)
    offsets = jnp.cumsum(counts) - counts              # (E,) group starts
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - offsets[sorted_eids]
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    return pos, counts


def moe_forward(p: Params, spec: ModelSpec, x: jnp.ndarray, *,
                capacity_factor: float = 1.25,
                router_impl: str = "softmax",
                tp_f=None, tp_g=None,
                sp_axis: Optional[str] = None) -> MoEOutput:
    """x: (b, s, h) -> (b, s, h).

    DeepSeek-v3 uses sigmoid scoring + top-k renormalisation; classic top-k
    softmax also supported (OLMoE/Qwen3 use softmax).

    ``tp_f``/``tp_g`` (optional) are the pipeline executor's manual
    tensor-parallel entry/exit operators (``parallel.tp``): expert weights
    arrive sharded on their *ff* dim (ETP — every shard holds all experts,
    1/tp of each expert's hidden), the router/dispatch runs replicated and
    bit-identical on every shard, ``tp_f`` wraps the dispatch buffer and
    shared-expert input, ``tp_g`` sums the partial expert outputs.  The
    returned ``y`` and ``aux_loss`` are then replicated across TP.

    ``sp_axis`` marks the executor's sequence-parallel mode: ``x`` is a
    *seq shard* (each TP rank routes and dispatches its own disjoint token
    chunk — the router activations live 1/sp per shard), ``tp_f`` is then
    the ğ all-gather whose token dim for the (E, C, h) dispatch buffer is
    its capacity dim, so the expert FFN still sees every shard's tokens,
    and ``tp_g`` reduce-scatters each shard its own tokens' outputs.  The
    load-balance means are combined across shards (``pmean_sp``) before
    the aux product — per-shard token sets are disjoint and equal-sized,
    so the combined aux equals the sp=1 value exactly; the resulting
    seq-partial router gradient is completed by the executor's post-loop
    'model'-axis psum."""
    e = spec.moe
    b, s, h = x.shape
    T = b * s
    E, K = e.n_routed, e.n_active
    xt = x.reshape(T, h)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E) fp32
    if router_impl == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_vals, eids = jax.lax.top_k(scores, K)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eids = jax.lax.top_k(probs, K)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1)), axis=0) / K
    if sp_axis is not None:
        from repro.parallel.tp import pmean_sp
        me, ce = pmean_sp(me, sp_axis), pmean_sp(ce, sp_axis)
    aux = E * jnp.sum(me * ce)

    C = int(max(1, round(T * K / E * capacity_factor)))
    flat_eids = eids.reshape(T * K)
    pos, _ = _positions_in_expert(flat_eids, E)
    keep = (pos < C)
    pos_c = jnp.minimum(pos, C - 1)

    # dispatch: scatter kept tokens into the (E, C, h) buffer (EP-sharded)
    src = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, h), x.dtype).at[flat_eids, pos_c].add(src)
    if tp_f is not None:
        buf = tp_f(buf)

    # expert FFN (SwiGLU), batched over the expert dim
    a = jax.nn.silu(jnp.einsum("ech,ehf->ecf", buf, p["we_gate"]))
    a = a * jnp.einsum("ech,ehf->ecf", buf, p["we_up"])
    out_buf = jnp.einsum("ecf,efh->ech", a, p["we_down"])
    if tp_g is not None:
        out_buf = tp_g(out_buf)

    # combine: gather each assignment's expert output, weight, sum over K
    y_pairs = out_buf[flat_eids, pos_c] * (gates.reshape(T * K)
                                           * keep.astype(jnp.float32)
                                           )[:, None].astype(x.dtype)
    y = y_pairs.reshape(T, K, h).sum(axis=1)

    if e.n_shared:
        xs = tp_f(xt) if tp_f is not None else xt
        ys = mlp_apply(p["shared"], spec, xs)
        y = y + (tp_g(ys) if tp_g is not None else ys)
    return MoEOutput(y=y.reshape(b, s, h), aux_loss=aux, router_probs=probs)


def moe_forward_dense_ref(p: Params, spec: ModelSpec, x: jnp.ndarray, *,
                          router_impl: str = "softmax") -> jnp.ndarray:
    """Dropless dense reference: every token runs through its top-k experts
    via full (T, E) weighting.  O(T·E·h·f) — for tests on tiny sizes only."""
    e = spec.moe
    b, s, h = x.shape
    T = b * s
    xt = x.reshape(T, h)
    logits = xt.astype(jnp.float32) @ p["router"]
    if router_impl == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_vals, eids = jax.lax.top_k(scores, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eids = jax.lax.top_k(probs, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
    w = jnp.zeros((T, e.n_routed), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], eids].set(gates)
    # per-expert dense pass
    a = jax.nn.silu(jnp.einsum("th,ehf->etf", xt, p["we_gate"]))
    a = a * jnp.einsum("th,ehf->etf", xt, p["we_up"])
    ye = jnp.einsum("etf,efh->eth", a, p["we_down"])       # (E, T, h)
    y = jnp.einsum("te,eth->th", w.astype(x.dtype), ye)
    if e.n_shared:
        y = y + mlp_apply(p["shared"], spec, xt)
    return y.reshape(b, s, h)
