"""Mixture-of-experts layer (paper §1.1/§3.3/§5.2).

Capacity-based token dispatch, built from sort/scatter primitives so the
per-device expert buffer is (E, C, h) — shardable on the expert axis (EP over
the mesh's ``model`` axis) — rather than the (T, E, C) one-hot einsum of
GShard, which is infeasible at long sequence lengths.

Matches the paper's accounting: balanced load gives E_token = b·s·N_r/N
tokens per expert (capacity_factor=1.0 reproduces §5.2 exactly; default 1.25
gives headroom like production routers).  Shared experts process every token
and are replicated across EP ranks (paper §3.3).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import ModelSpec
from .layers import Params, dense_init, mlp_apply, mlp_init


class MoEOutput(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray       # load-balance auxiliary loss
    # (T, E) fp32 normalised router probabilities (paper keeps 4bsN router
    # acts).  T is the *routed* token set: the full batch on the replicated
    # paths, the rank's own disjoint token chunk inside token-sharded
    # executors (SP and/or EP) — consumers wanting global stats must gather
    # over the token-sharding axis.
    router_probs: jnp.ndarray


def moe_init(key: jax.Array, spec: ModelSpec, dtype=jnp.bfloat16) -> Params:
    e = spec.moe
    kr, ke, ks = jax.random.split(key, 3)
    kg, ku, kd = jax.random.split(ke, 3)
    E, h, f = e.n_routed, spec.h, e.d_ff_expert
    p = {
        "router": dense_init(kr, (h, E), jnp.float32, scale=h ** -0.5),
        # stacked expert weights: leading dim = expert (EP-sharded)
        "we_gate": dense_init(kg, (E, h, f), dtype),
        "we_up": dense_init(ku, (E, h, f), dtype),
        "we_down": dense_init(kd, (E, f, h), dtype),
    }
    if e.n_shared:
        p["shared"] = mlp_init(ks, spec, f * e.n_shared, dtype)
    return p


def _route(router_w: jnp.ndarray, spec: ModelSpec, xt: jnp.ndarray,
           router_impl: str) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Route flat tokens (T, h) -> (probs (T, E) fp32, gates (T, K) fp32,
    eids (T, K) int32).  DeepSeek-v3 sigmoid scoring + top-k renorm, or
    classic top-k softmax (OLMoE/Qwen3).  Shared by the scatter, EP-a2a and
    GSPMD-a2a dispatch paths so routing can never drift between them."""
    e = spec.moe
    logits = xt.astype(jnp.float32) @ router_w
    if router_impl == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_vals, eids = jax.lax.top_k(scores, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eids = jax.lax.top_k(probs, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
    return probs, gates, eids


def _send_eid_buffer(dest: jnp.ndarray, pos: jnp.ndarray,
                     local_eid: jnp.ndarray, n_dest: int, c_send: int,
                     e_loc: int) -> jnp.ndarray:
    """(n_dest, c_send) int32 buffer of local expert ids for the a2a send
    step; slots no kept assignment wrote carry ``e_loc``, the padding
    marker the receiver masks on.  ``pos`` is the UNCLAMPED rank of each
    assignment within its destination bucket: out-of-capacity assignments
    index past ``c_send`` and the scatter drops them (``mode="drop"``).
    Clamping them to ``c_send - 1`` instead — and writing the marker there
    — collided with the slot's real write (scatter-set with duplicate
    indices keeps an arbitrary one), so on bucket overflow a *kept*
    token's expert id could be overwritten by the marker and its expert
    output silently zeroed."""
    return jnp.full((n_dest, c_send), e_loc, jnp.int32) \
        .at[dest, pos].set(local_eid, mode="drop")


def _positions_in_expert(eids: jnp.ndarray, n_expert: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """For flat expert assignments (TK,), compute each assignment's rank
    within its expert and the per-expert totals.

    Sort-based: O(TK log TK) compares.  (A (TK, E) one-hot cumsum is the
    obvious alternative but XLA lowers it to a reduce-window that both
    costs and *counts* O(TK²·E) — it dominated the roofline compute term
    100× over the expert matmuls before this change; see EXPERIMENTS.md
    §Perf iteration log.)"""
    tk = eids.shape[0]
    order = jnp.argsort(eids, stable=True)
    sorted_eids = eids[order]
    counts = jnp.zeros((n_expert,), jnp.int32).at[eids].add(1)
    offsets = jnp.cumsum(counts) - counts              # (E,) group starts
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - offsets[sorted_eids]
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    return pos, counts


def moe_forward(p: Params, spec: ModelSpec, x: jnp.ndarray, *,
                capacity_factor: float = 1.25,
                router_impl: str = "softmax",
                tp_f=None, tp_g=None,
                sp_axis: Optional[str] = None,
                ep: int = 1,
                ep_axis: Optional[str] = None,
                backend: str = "reference") -> MoEOutput:
    """x: (b, s, h) -> (b, s, h).

    DeepSeek-v3 uses sigmoid scoring + top-k renormalisation; classic top-k
    softmax also supported (OLMoE/Qwen3 use softmax).

    ``tp_f``/``tp_g`` (optional) are the pipeline executor's manual
    tensor-parallel entry/exit operators (``parallel.tp``): expert weights
    arrive sharded on their *ff* dim (ETP — every shard holds all experts,
    1/tp of each expert's hidden), the router/dispatch runs replicated and
    bit-identical on every shard, ``tp_f`` wraps the dispatch buffer and
    shared-expert input, ``tp_g`` sums the partial expert outputs.  The
    returned ``y`` and ``aux_loss`` are then replicated across TP.

    ``sp_axis`` marks the executor's sequence-parallel mode: ``x`` is a
    *seq shard* (each TP rank routes and dispatches its own disjoint token
    chunk — the router activations live 1/sp per shard), ``tp_f`` is then
    the ğ all-gather whose token dim for the (E, C, h) dispatch buffer is
    its capacity dim, so the expert FFN still sees every shard's tokens,
    and ``tp_g`` reduce-scatters each shard its own tokens' outputs.  The
    load-balance means are combined across shards (``pmean_sp``) before
    the aux product — per-shard token sets are disjoint and equal-sized,
    so the combined aux equals the sp=1 value exactly; the resulting
    seq-partial router gradient is completed by the executor's post-loop
    'model'-axis psum.

    ``ep``/``ep_axis`` (paper §3.3) switch the routed experts to true
    expert parallelism over ``ep_axis`` (the executor's 'model' axis,
    ``ep`` == its size): expert weights arrive sharded on their *expert*
    dim (``(E/ep, h, h_E)`` per rank, full hidden), each rank routes its
    own disjoint token chunk — the seq shard under SP, an explicit
    ``shard_tokens_ep`` slice of the replicated residual otherwise — and
    the dispatch is :func:`_moe_dispatch_ep`'s send-bucket / all-to-all /
    local grouped FFN / all-to-all-back exchange.  The shared expert stays
    on the ETP path (``tp_f``/``tp_g``, every token), and the router —
    consumed inside the token-sharded region — accumulates token-partial
    gradients the executor completes with its post-loop 'model' psum
    (the same completion SP already requires)."""
    e = spec.moe
    b, s, h = x.shape
    T = b * s
    E, K = e.n_routed, e.n_active
    xt = x.reshape(T, h)

    if ep > 1:
        if ep_axis is None:
            raise ValueError("moe_forward: ep > 1 needs ep_axis (the mesh "
                             "axis the a2a dispatch group lives on)")
        if E % ep:
            raise ValueError(f"ep={ep} does not divide n_routed={E}")
        return _moe_forward_ep(p, spec, x, capacity_factor=capacity_factor,
                               router_impl=router_impl, tp_f=tp_f, tp_g=tp_g,
                               sp_axis=sp_axis, ep=ep, ep_axis=ep_axis,
                               backend=backend)

    probs, gates, eids = _route(p["router"], spec, xt, router_impl)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1)), axis=0) / K
    if sp_axis is not None:
        from repro.parallel.tp import pmean_sp
        me, ce = pmean_sp(me, sp_axis), pmean_sp(ce, sp_axis)
    aux = E * jnp.sum(me * ce)

    C = int(max(1, round(T * K / E * capacity_factor)))
    flat_eids = eids.reshape(T * K)
    pos, _ = _positions_in_expert(flat_eids, E)
    keep = (pos < C)
    pos_c = jnp.minimum(pos, C - 1)

    # dispatch: scatter kept tokens into the (E, C, h) buffer (EP-sharded)
    src = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, h), x.dtype).at[flat_eids, pos_c].add(src)
    if tp_f is not None:
        buf = tp_f(buf)

    # expert FFN (SwiGLU), batched over the expert dim — the backend's
    # grouped_mlp (pallas: three grouped GEMMs over the flattened
    # static-capacity rows; reference: the einsum triple)
    from .backend import grouped_mlp
    out_buf = grouped_mlp(buf, p["we_gate"], p["we_up"], p["we_down"],
                          backend=backend)
    if tp_g is not None:
        out_buf = tp_g(out_buf)

    # combine: gather each assignment's expert output, weight, sum over K
    y_pairs = out_buf[flat_eids, pos_c] * (gates.reshape(T * K)
                                           * keep.astype(jnp.float32)
                                           )[:, None].astype(x.dtype)
    y = y_pairs.reshape(T, K, h).sum(axis=1)

    if e.n_shared:
        xs = tp_f(xt) if tp_f is not None else xt
        ys = mlp_apply(p["shared"], spec, xs)
        y = y + (tp_g(ys) if tp_g is not None else ys)
    return MoEOutput(y=y.reshape(b, s, h), aux_loss=aux, router_probs=probs)


def _moe_forward_ep(p: Params, spec: ModelSpec, x: jnp.ndarray, *,
                    capacity_factor: float, router_impl: str,
                    tp_f, tp_g, sp_axis: Optional[str],
                    ep: int, ep_axis: str,
                    backend: str = "reference") -> MoEOutput:
    """True expert parallelism inside the manual-collectives executor
    (paper §3.3): weights sharded ``(E/ep, h, h_E)`` on the expert dim over
    ``ep_axis``, token exchange via two ``lax.all_to_all``\\ s.

    Per rank: route the rank's own disjoint token chunk (the seq shard
    under SP; a ``shard_tokens_ep`` slice of the replicated residual
    otherwise), bucket assignments by destination expert shard
    (``dest = eid // (E/ep)``, capacity ``C_send = tk/ep·cf`` applied
    *once*), a2a the ``(ep, C_send, h)`` send buffer, run the local
    ``(E/ep, C, h)`` grouped FFN — ``C`` is the same global per-expert
    capacity as ep=1, so the buffer is exactly the analytic ``/ep``
    dispatch term — then a2a the outputs back and combine with the
    locally-kept gates.  The router is consumed inside the token-sharded
    region, so its local gradient is token-partial; the executor's
    post-loop 'model' psum completes it (``train.pipeline_loop``)."""
    from repro.parallel.tp import (pmean_sp, shard_tokens_ep,
                                   unshard_tokens_ep)
    e = spec.moe
    b, s, h = x.shape
    E, K = e.n_routed, e.n_active
    E_loc = E // ep
    xt_full = x.reshape(b * s, h)
    if sp_axis is None:
        if (b * s) % ep:
            raise ValueError(
                f"ep={ep} does not divide the per-rank token count "
                f"{b * s}; the EP token slice has no pad fallback")
        xt = shard_tokens_ep(xt_full, ep_axis, 0)
    else:
        xt = xt_full            # SP residual is already the token shard
    t_loc = xt.shape[0]

    probs, gates, eids = _route(p["router"], spec, xt, router_impl)
    # per-chunk token sets are disjoint and equal-sized: the pmean of the
    # per-chunk means is the exact global mean, so aux == the ep=1 value
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1)), axis=0) / K
    me, ce = pmean_sp(me, ep_axis), pmean_sp(ce, ep_axis)
    aux = E * jnp.sum(me * ce)

    tk = t_loc * K
    flat_eids = eids.reshape(tk)
    flat_gates = gates.reshape(tk)
    dest = flat_eids // E_loc
    local_eid = flat_eids % E_loc

    # send: bucket by destination shard, capacity_factor applied once here
    c_send = int(max(1, round(tk / ep * capacity_factor)))
    pos_d, _ = _positions_in_expert(dest, ep)
    keep_s = pos_d < c_send
    pos_dc = jnp.minimum(pos_d, c_send - 1)
    src = jnp.repeat(xt, K, axis=0) * keep_s[:, None].astype(x.dtype)
    send = jnp.zeros((ep, c_send, h), x.dtype).at[dest, pos_dc].add(src)
    send_eid = _send_eid_buffer(dest, pos_d, local_eid, ep, c_send, E_loc)

    recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                              concat_axis=0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)

    # Dual-stream shape: the shared expert depends only on the residual,
    # not on the a2a payloads, so it is computed *between* the dispatch
    # a2a's issue and its first consumer — XLA's scheduler is free to run
    # the ETP matmuls while the token exchange is in flight (the DualPipe
    # overlap structure at slot granularity).
    ys = None
    if e.n_shared:
        xs = tp_f(xt_full) if tp_f is not None else xt_full
        ys = mlp_apply(p["shared"], spec, xs)
        if tp_g is not None:
            ys = tp_g(ys)

    # local grouped FFN over the (E/ep, C, h) buffer; C = the global
    # per-expert capacity (tk·ep assignments over E experts), NOT scaled
    # by capacity_factor a second time
    rows = recv.reshape(ep * c_send, h)
    row_eid = recv_eid.reshape(ep * c_send)
    pos_e, _ = _positions_in_expert(row_eid, E_loc + 1)
    c_loc = int(max(1, round(tk * ep / E * capacity_factor)))
    keep_e = (pos_e < c_loc) & (row_eid < E_loc)
    pos_ec = jnp.minimum(pos_e, c_loc - 1)
    eid_c = jnp.minimum(row_eid, E_loc - 1)
    buf = jnp.zeros((E_loc, c_loc, h), x.dtype) \
        .at[eid_c, pos_ec].add(rows * keep_e[:, None].astype(x.dtype))

    # local grouped FFN on the (E/ep, C, h) post-a2a buffer — the EP shard
    # the pallas grouped GEMM sees (expert-dim-sharded weights, full hidden)
    from .backend import grouped_mlp
    out_buf = grouped_mlp(buf, p["we_gate"], p["we_up"], p["we_down"],
                          backend=backend)

    back = (out_buf[eid_c, pos_ec] * keep_e[:, None].astype(x.dtype)) \
        .reshape(ep, c_send, h)
    ret = jax.lax.all_to_all(back, ep_axis, split_axis=0,
                             concat_axis=0, tiled=False)

    y_pairs = ret[dest, pos_dc] * (flat_gates * keep_s.astype(jnp.float32)
                                   )[:, None].astype(x.dtype)
    y = y_pairs.reshape(t_loc, K, h).sum(axis=1)
    if sp_axis is None:
        y = unshard_tokens_ep(y, ep_axis, 0)       # rejoin replicated stream

    if ys is not None:
        # shared experts process every token and stay on the ETP path
        y = y + ys
    # probs are the rank's token chunk only (documented: per-shard under EP)
    return MoEOutput(y=y.reshape(b, s, h), aux_loss=aux, router_probs=probs)


def moe_forward_dense_ref(p: Params, spec: ModelSpec, x: jnp.ndarray, *,
                          router_impl: str = "softmax") -> jnp.ndarray:
    """Dropless dense reference: every token runs through its top-k experts
    via full (T, E) weighting.  O(T·E·h·f) — for tests on tiny sizes only."""
    e = spec.moe
    b, s, h = x.shape
    T = b * s
    xt = x.reshape(T, h)
    logits = xt.astype(jnp.float32) @ p["router"]
    if router_impl == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gate_vals, eids = jax.lax.top_k(scores, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eids = jax.lax.top_k(probs, e.n_active)
        gates = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-20)
    w = jnp.zeros((T, e.n_routed), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], eids].set(gates)
    # per-expert dense pass
    a = jax.nn.silu(jnp.einsum("th,ehf->etf", xt, p["we_gate"]))
    a = a * jnp.einsum("th,ehf->etf", xt, p["we_up"])
    ye = jnp.einsum("etf,efh->eth", a, p["we_down"])       # (E, T, h)
    y = jnp.einsum("te,eth->th", w.astype(x.dtype), ye)
    if e.n_shared:
        y = y + mlp_apply(p["shared"], spec, xt)
    return y.reshape(b, s, h)
