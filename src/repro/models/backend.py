"""Kernel-backend dispatch: the ONE point where model code picks between
the jnp reference math and the Pallas fast path.

Every hot op the paper's activation tables care about — ``rmsnorm``,
``attention`` (GQA context), ``mla_attention`` (dq≠dv flash), and the MoE
``grouped_mlp`` — resolves here from ``ModelOptions.backend``
(``"reference" | "pallas"``; the legacy ``use_pallas=True`` flag is an
alias for ``"pallas"``).  Call sites: the non-pipeline path
(``transformer._norm`` / ``block_apply``), the 3D executor
(``pipeline._slot_apply`` + the chunk heads in ``train.pipeline_loop``),
the MLA towers (``mla._towers`` / ``mla_forward``) and the expert FFN
(``moe.moe_forward`` / ``_moe_forward_ep``).

Sharding contract (why this works inside the manual-TP/SP ``shard_map``
executor with *no* kernel-side collectives): operands arrive pre-sharded.

* ``rmsnorm`` runs on the residual stream — replicated across TP, or the
  seq shard under SP; either way a plain (rows, h) problem per device.
* flash attention runs *inside* a TP region: the f/ğ entry operator has
  already gathered the full sequence, and the head dim is the TP-local
  ``n_h/tp`` — the kernel's (b·n_h_local, s) grid never sees a collective.
* ``grouped_mlp`` consumes the MoE dispatch buffer: ``(E, C, h)`` under
  ETP (ff-sharded weights, full capacity after the SP gather) or
  ``(E/ep, C_loc, h)`` under EP (expert-sharded weights, post-a2a rows).
  Capacity is static and rows are pre-grouped per expert, so the grouped
  GEMM's ``expert_map`` is the static ``repeat(arange(E), C/block_m)`` —
  no host-side regrouping (``pad_groups``) in the traced path.

Autodiff contract: ``pl.pallas_call`` has no general transpose rule, so
each pallas op is a ``jax.custom_vjp`` — forward through the kernel,
backward by re-deriving the vjp of the jnp oracle (``kernels.ref``) from
the saved *inputs*.  That is exactly the flash recompute story: nothing
O(s²) is resident between forward and backward; the score matrix only
materialises transiently inside one layer's backward.  It also pins the
gradients to the reference path, so the executor equivalence harnesses
compare like with like.
"""

from __future__ import annotations

import functools
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp

BACKENDS = ("reference", "pallas")
# attention impls that never materialise the resident 5·b·n_h·s² buffers
# (the memory model's attn_impl="flash" accounting — see
# core.activations.FLASH_ATTN_IMPLS, which must stay in sync)
FLASH_IMPLS = ("pallas", "flash")


# ---------------------------------------------------------------------------
# Backend / attention-impl resolution (replaces the ad-hoc use_pallas +
# attn_impl special cases that used to live in transformer.block_apply)
# ---------------------------------------------------------------------------

def resolve_backend(opts) -> str:
    """ModelOptions -> backend name.  ``use_pallas=True`` is the deprecated
    spelling of ``backend="pallas"``; ``opts=None`` means reference."""
    if opts is None:
        return "reference"
    backend = getattr(opts, "backend", "reference")
    if getattr(opts, "use_pallas", False):
        backend = "pallas"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    return backend


def attention_fallbacks(opts, *, causal: bool = True,
                        window: Optional[int] = None) -> List[str]:
    """Reasons the pallas flash kernel cannot serve this attention call,
    as human-readable strings (empty list = the fast path applies) — the
    ``core.notation.tp_violations``-style report for kernel dispatch."""
    if resolve_backend(opts) != "pallas":
        return []
    bad = []
    if not causal:
        bad.append("causal=False (flash kernel is causal-only)")
    if window is not None:
        bad.append(f"sliding_window={window} (flash kernel has no window mask)")
    return bad


def resolve_attn_impl(opts, *, causal: bool = True,
                      window: Optional[int] = None) -> str:
    """The attention impl a block should run: ``"pallas"`` when the backend
    is pallas and the kernel's contract holds, else ``opts.attn_impl`` —
    loudly, never silently (the old ``use_pallas and causal`` branch
    dropped to naive without a word)."""
    base = getattr(opts, "attn_impl", "naive") if opts is not None else "naive"
    if resolve_backend(opts) != "pallas":
        return base
    bad = attention_fallbacks(opts, causal=causal, window=window)
    if bad:
        warnings.warn(
            "backend='pallas': attention falling back to "
            f"'{base}' — {'; '.join(bad)}", RuntimeWarning, stacklevel=3)
        return base
    return "pallas"


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pallas_rmsnorm(eps: float, gemma_style: bool, x, scale):
    from repro.kernels import ops as K
    return K.rmsnorm(x, scale, eps=eps, gemma_style=gemma_style)


def _pallas_rmsnorm_fwd(eps, gemma_style, x, scale):
    return _pallas_rmsnorm(eps, gemma_style, x, scale), (x, scale)


def _pallas_rmsnorm_bwd(eps, gemma_style, res, g):
    from repro.kernels.ref import rmsnorm_ref
    x, scale = res
    _, vjp = jax.vjp(
        lambda x_, s_: rmsnorm_ref(x_, s_, eps=eps, gemma_style=gemma_style),
        x, scale)
    return vjp(g)


_pallas_rmsnorm.defvjp(_pallas_rmsnorm_fwd, _pallas_rmsnorm_bwd)


def rmsnorm(p, x, eps: float = 1e-6, *, gemma_style: bool = False,
            backend: str = "reference"):
    """Backend-dispatched RMSNorm; same (params, x, eps) signature as
    ``layers.rmsnorm`` so call sites swap in place."""
    if backend == "pallas":
        return _pallas_rmsnorm(float(eps), bool(gemma_style), x, p["scale"])
    from .layers import rmsnorm as rmsnorm_jnp
    return rmsnorm_jnp(p, x, eps, gemma_style=gemma_style)


# ---------------------------------------------------------------------------
# attention (GQA and MLA share this: the kernel supports dq != dv)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_attention(scale: float, q, k, v):
    from repro.kernels import ops as K
    return K.flash_attention(q, k, v, scale=scale, causal=True)


def _pallas_attention_fwd(scale, q, k, v):
    return _pallas_attention(scale, q, k, v), (q, k, v)


def _pallas_attention_bwd(scale, res, g):
    # Recompute-style backward through the jnp oracle: only q/k/v were
    # saved, so the s² score matrix exists transiently inside this vjp and
    # is never resident across the forward/backward gap — the accounting
    # core.activations prices as attn_impl="flash".
    from repro.kernels.ref import flash_attention_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, scale=scale,
                                               causal=True), q, k, v)
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def attention(q, k, v, *, scale: float, impl: str = "naive",
              causal: bool = True, window: Optional[int] = None):
    """Context for (b, s, n_h, d) heads — n_h is whatever the caller holds
    (the TP-local shard inside the executor).  ``impl``: "pallas"/"flash"
    (kernel), "chunked" (jnp online-softmax), anything else = naive.  An
    unsupported flash request falls back to naive with a RuntimeWarning
    naming the reason (never silently)."""
    if impl in FLASH_IMPLS:
        if causal and window is None:
            return _pallas_attention(float(scale), q, k, v)
        reasons = []
        if not causal:
            reasons.append("causal=False (flash kernel is causal-only)")
        if window is not None:
            reasons.append(f"sliding_window={window} "
                           "(flash kernel has no window mask)")
        warnings.warn(
            f"attention: impl={impl!r} unsupported here — "
            f"{'; '.join(reasons)}; falling back to naive",
            RuntimeWarning, stacklevel=2)
        impl = "naive"
    if impl == "chunked":
        from .attention import chunked_attention
        return chunked_attention(q, k, v, scale, window=window)
    from .attention import causal_mask, naive_attention
    s = q.shape[1]
    mask = causal_mask(s, window) if causal \
        else jnp.ones((s, k.shape[1]), bool)
    return naive_attention(q, k, v, mask, scale)


def mla_attention(q, k, v, *, scale: float, impl: str = "naive"):
    """MLA context (dq = d_h + d_hr, dv = d_v): same dispatch, causal-only,
    no sliding window — kept as its own name so call sites read as the
    paper's Figure 2."""
    return attention(q, k, v, scale=scale, impl=impl, causal=True)


# ---------------------------------------------------------------------------
# grouped MLP (the MoE expert FFN over the static-capacity dispatch buffer)
# ---------------------------------------------------------------------------

def _gmm_block(n: int, pref: int = 128) -> int:
    """Block size for one GEMM dim: the MXU-friendly 128 when it divides,
    else the whole dim as a single tile (always valid — the dispatch
    buffer's capacity/ff dims are static; a giant single tile only costs
    VMEM on real TPUs, where capacity_factor should be chosen so C, f and
    h are multiples of 128)."""
    return pref if n % pref == 0 else n


@jax.custom_vjp
def _pallas_grouped_mlp(buf, wg, wu, wd):
    from repro.kernels import ops as K
    E, C, h = buf.shape
    f = wg.shape[-1]
    bm = _gmm_block(C)
    # rows are pre-grouped C-per-expert, so the expert map is static
    emap = jnp.repeat(jnp.arange(E, dtype=jnp.int32), C // bm)
    lhs = buf.reshape(E * C, h)
    bn_f, bn_h = _gmm_block(f), _gmm_block(h)
    gate = K.gmm(lhs, wg, emap, block_m=bm, block_n=bn_f)
    up = K.gmm(lhs, wu, emap, block_m=bm, block_n=bn_f)
    a = jax.nn.silu(gate) * up
    out = K.gmm(a, wd, emap, block_m=bm, block_n=bn_h)
    return out.reshape(E, C, h)


def _grouped_mlp_ref(buf, wg, wu, wd):
    a = jax.nn.silu(jnp.einsum("ech,ehf->ecf", buf, wg))
    a = a * jnp.einsum("ech,ehf->ecf", buf, wu)
    return jnp.einsum("ecf,efh->ech", a, wd)


def _pallas_grouped_mlp_fwd(buf, wg, wu, wd):
    return _pallas_grouped_mlp(buf, wg, wu, wd), (buf, wg, wu, wd)


def _pallas_grouped_mlp_bwd(res, g):
    _, vjp = jax.vjp(_grouped_mlp_ref, *res)
    return vjp(g)


_pallas_grouped_mlp.defvjp(_pallas_grouped_mlp_fwd, _pallas_grouped_mlp_bwd)


def grouped_mlp(buf, wg, wu, wd, *, backend: str = "reference"):
    """SwiGLU expert FFN batched over the expert dim.

    buf: (E, C, h) dispatch buffer (E and C are whatever the caller's
    parallelism left local — E/ep experts under EP, C·sp capacity after
    the SP gather); wg/wu: (E, h, f) with f possibly ff-sharded (ETP);
    wd: (E, f, h).  The pallas path runs three grouped GEMMs on the
    flattened (E·C, h) rows with a static expert map."""
    if backend == "pallas":
        return _pallas_grouped_mlp(buf, wg, wu, wd)
    return _grouped_mlp_ref(buf, wg, wu, wd)
