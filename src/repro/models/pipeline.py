"""Pipeline-parallel stage partitioning of a Model.

The layer→stage assignment is ``core.params.pp_stage_layers`` — the exact
split behind the paper's Table 4 — so the runtime executor, the per-stage
dry-run probes and the analytical model (``estimate_memory(stage=...)``,
``table4_stages``) can never disagree about which layers live where.

Two views of the same partition are provided:

* **Heterogeneous stage slices** (``stage_params_slice`` +
  ``make_stage_fn``): stage s's true parameter subtree (embedding only on
  stage 0, final norm / head only on the last stage, its own contiguous
  dense/MoE sub-stacks) and a forward for exactly those layers.  Used by the
  dry-run to lower/compile each stage as its own program and read XLA's
  per-stage ``memory_analysis`` — the numbers compared against
  ``estimate_memory(spec, cfg, stage=s, in_flight_microbatches=...)``.

* **Stage-stacked (SPMD) layout** (``stack_pipeline_params`` /
  ``unstack_pipeline_grads`` + ``pipeline_stage_apply``): every parameter
  leaf gains a leading ``pp`` dim sharded over the ``pipe`` mesh axis, with
  per-stage layer stacks padded to the widest stage (masked identity slots)
  and a *union* slot structure (a slot carries both the dense-MLP and MoE
  subtrees when the model mixes kinds; a per-slot flag selects).  This is
  what the 1F1B executor (``train.pipeline_loop``) runs under ``shard_map``
  — one program, stage identity = ``lax.axis_index('pipe')``.

The stacked layout trades memory for SPMD uniformity (padded slots, the
unused half of mixed dense/MoE slots, zero embed rows on interior stages);
the per-stage dry-run path has no such padding, so memory validation always
uses the heterogeneous view.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.notation import AttentionKind, FamilyKind, ModelSpec
from repro.core.params import pp_stage_layers
from repro.parallel.axes import logical_constraint
from . import attention as A
from . import mla as M
from .layers import embed_apply, mlp_apply, rmsnorm
from .moe import moe_forward
from .transformer import ModelOptions, _remat, stack_apply

PyTree = Any


def check_pipeline_supported(spec: ModelSpec) -> None:
    """The pipeline runtime covers the paper's training families: decoder-only
    dense and MoE transformers (MLA or GQA/MHA attention).  Recurrent, enc-dec
    and stub-frontend families keep the pp=1 path."""
    if spec.ssm is not None:
        raise NotImplementedError("pipeline runtime: SSM/hybrid unsupported")
    if spec.encoder is not None:
        raise NotImplementedError("pipeline runtime: enc-dec unsupported")
    if spec.family == FamilyKind.VLM:
        raise NotImplementedError("pipeline runtime: VLM frontend unsupported")
    if spec.attention == AttentionKind.NONE:
        raise NotImplementedError("pipeline runtime: attention-free unsupported")


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """Layer→stage assignment plus the index/mask arrays both runtime views
    derive from it.  All arrays are numpy (static schedule data)."""

    pp: int
    n_layers: int
    n_dense: int                      # dense layers are global ids [0, n_dense)
    stages: Tuple[Tuple[int, ...], ...]
    l_max: int                        # widest stage (slot count of the SPMD view)
    idx: np.ndarray                   # (pp, l_max) global layer id; pads repeat
    mask: np.ndarray                  # (pp, l_max) f32: 1 real slot, 0 pad
    moe_flag: np.ndarray              # (pp, l_max) f32: 1 MoE layer, 0 dense
    stage_of: np.ndarray              # (n_layers,) stage owning each layer
    slot_of: np.ndarray               # (n_layers,) slot within that stage


def partition(spec: ModelSpec, pp: int) -> StagePartition:
    if not 1 <= pp <= spec.n_layers:
        raise ValueError(f"pp={pp} must be in [1, n_layers={spec.n_layers}]")
    stages = tuple(tuple(ls) for ls in pp_stage_layers(spec.n_layers, pp))
    n_dense = spec.n_layers - spec.n_moe_layers()
    l_max = max(len(ls) for ls in stages)
    idx = np.zeros((pp, l_max), np.int32)
    mask = np.zeros((pp, l_max), np.float32)
    moe_flag = np.zeros((pp, l_max), np.float32)
    stage_of = np.zeros(spec.n_layers, np.int32)
    slot_of = np.zeros(spec.n_layers, np.int32)
    for i, ls in enumerate(stages):
        for j in range(l_max):
            l = ls[j] if j < len(ls) else ls[-1]      # pads repeat a real layer
            idx[i, j] = l
            if j < len(ls):
                mask[i, j] = 1.0
                moe_flag[i, j] = float(l >= n_dense)
                stage_of[l] = i
                slot_of[l] = j
    return StagePartition(pp=pp, n_layers=spec.n_layers, n_dense=n_dense,
                          stages=stages, l_max=l_max, idx=idx, mask=mask,
                          moe_flag=moe_flag, stage_of=stage_of,
                          slot_of=slot_of)


# ---------------------------------------------------------------------------
# Heterogeneous view: true per-stage parameter subtrees + per-stage forward
# ---------------------------------------------------------------------------

def stage_params_slice(params: PyTree, spec: ModelSpec, pp: int,
                       stage: int) -> PyTree:
    """Stage ``stage``'s parameters in the Model layout (keys kept so the
    §3 TP/ZeRO sharding rules in ``parallel.sharding`` apply unchanged)."""
    check_pipeline_supported(spec)
    part = partition(spec, pp)
    layers = part.stages[stage]
    lo, hi = layers[0], layers[-1] + 1
    nd = part.n_dense
    out: Dict[str, Any] = {}
    if stage == 0:
        out["embed"] = params["embed"]
    d_lo, d_hi = lo, min(hi, nd)
    if d_hi > d_lo:
        out["dense_layers"] = jax.tree.map(lambda a: a[d_lo:d_hi],
                                           params["dense_layers"])
    m_lo, m_hi = max(lo, nd) - nd, hi - nd
    if m_hi > max(m_lo, 0):
        out["moe_layers"] = jax.tree.map(lambda a: a[m_lo:m_hi],
                                         params["moe_layers"])
    if stage == pp - 1:
        out["final_norm"] = params["final_norm"]
        if spec.tie_embeddings:
            out["embed"] = params["embed"]
        elif "head" in params:
            out["head"] = params["head"]
    return out


def make_stage_fn(spec: ModelSpec, opts: ModelOptions, pp: int, stage: int):
    """fn(stage_params, x, tokens) -> (out, aux).

    Stage 0 embeds ``tokens`` (``x`` is ignored); interior stages transform
    the boundary activation ``x``; the last stage returns vocab logits
    (callers compute the loss — the executor and the dry-run probes need
    different reductions).  With pp=1 this is exactly ``Model.forward`` for
    the supported families.
    """
    check_pipeline_supported(spec)
    part = partition(spec, pp)
    gemma = spec.name.startswith("gemma")
    is_first, is_last = stage == 0, stage == pp - 1
    window = spec.sliding_window

    def fn(stage_params: PyTree, x: Optional[jnp.ndarray],
           tokens: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if is_first:
            x = embed_apply(stage_params["embed"], tokens,
                            scale_by_dim=gemma, h=spec.h)
        b, s = x.shape[0], x.shape[1]
        x = logical_constraint(x, ("batch", "seq", "embed"))
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        aux = jnp.zeros((), jnp.float32)
        if "dense_layers" in stage_params:
            x, a = stack_apply(stage_params["dense_layers"], spec, opts, x,
                               positions, False, window=window)
            aux = aux + a
        if "moe_layers" in stage_params:
            x, a = stack_apply(stage_params["moe_layers"], spec, opts, x,
                               positions, True, window=window)
            aux = aux + a
        if is_last:
            x = rmsnorm(stage_params["final_norm"], x, spec.norm_eps,
                        gemma_style=gemma)
            if spec.tie_embeddings:
                logits = x @ stage_params["embed"]["w"].T
            else:
                logits = x @ stage_params["head"]["w"]
            logits = logical_constraint(logits, ("batch", "seq", "vocab"))
            return logits, aux
        return x, aux

    return fn


# ---------------------------------------------------------------------------
# Stage-stacked (SPMD) view: leading pp dim for shard_map over 'pipe'
# ---------------------------------------------------------------------------

def _take_layers(leaf: jnp.ndarray, index: np.ndarray) -> jnp.ndarray:
    flat = jnp.take(leaf, jnp.asarray(index.reshape(-1)), axis=0)
    return flat.reshape(index.shape + leaf.shape[1:])


def stack_pipeline_params(params: PyTree, spec: ModelSpec, pp: int) -> PyTree:
    """Model params → stage-stacked layout.

    layers: union slot structure, leaves (pp, l_max, ...); pad slots repeat a
    real layer of the stage (masked to identity at apply time) and the unused
    kind of a mixed dense/MoE slot holds a clipped-gather copy (never selected,
    so it receives exactly zero gradient).  embed/final_norm/head: (pp, ...)
    rows, zero except on the stage that owns them.
    """
    check_pipeline_supported(spec)
    part = partition(spec, pp)
    nd = part.n_dense
    dense = params.get("dense_layers") or {}
    moe = params.get("moe_layers") or {}
    idx = part.idx
    idx_d = np.clip(idx, 0, max(nd - 1, 0))
    idx_m = np.clip(idx - nd, 0, max(part.n_layers - nd - 1, 0))

    layers: Dict[str, Any] = {}
    for k in dense:
        if k in moe:
            layers[k] = jax.tree.map(
                lambda a, b: _take_layers(jnp.concatenate([a, b], axis=0), idx),
                dense[k], moe[k])
        else:
            layers[k] = jax.tree.map(lambda a: _take_layers(a, idx_d), dense[k])
    for k in moe:
        if k not in dense:
            layers[k] = jax.tree.map(lambda a: _take_layers(a, idx_m), moe[k])

    emb = params["embed"]["w"]
    emb_st = jnp.zeros((pp,) + emb.shape, emb.dtype).at[0].set(emb)
    if spec.tie_embeddings:
        emb_st = emb_st.at[pp - 1].set(emb)
    fin = params["final_norm"]["scale"]
    fin_st = jnp.zeros((pp,) + fin.shape, fin.dtype).at[pp - 1].set(fin)
    out: Dict[str, Any] = {"layers": layers,
                           "embed": {"w": emb_st},
                           "final_norm": {"scale": fin_st}}
    if "head" in params:
        hd = params["head"]["w"]
        out["head"] = {"w": jnp.zeros((pp,) + hd.shape, hd.dtype)
                       .at[pp - 1].set(hd)}
    return out


def unstack_pipeline_grads(gstack: PyTree, params: PyTree, spec: ModelSpec,
                           pp: int) -> PyTree:
    """Stage-stacked gradient pytree → the Model parameter layout (each global
    layer appears in exactly one (stage, slot); embed sums its stage-0 and —
    when tied — last-stage rows)."""
    part = partition(spec, pp)
    nd = part.n_dense
    sof = jnp.asarray(part.stage_of)
    slf = jnp.asarray(part.slot_of)

    def gather(leaf: jnp.ndarray) -> jnp.ndarray:
        return leaf[sof, slf]                      # (n_layers, ...)

    dense = params.get("dense_layers") or {}
    moe = params.get("moe_layers") or {}
    out: Dict[str, Any] = {"dense_layers": {}, "moe_layers": {}}
    for k in dense:
        out["dense_layers"][k] = jax.tree.map(
            lambda a: gather(a)[:nd], gstack["layers"][k])
    for k in moe:
        out["moe_layers"][k] = jax.tree.map(
            lambda a: gather(a)[nd:], gstack["layers"][k])
    g_emb = gstack["embed"]["w"][0]
    if spec.tie_embeddings and pp > 1:
        g_emb = g_emb + gstack["embed"]["w"][pp - 1]
    out["embed"] = {"w": g_emb}
    out["final_norm"] = {"scale": gstack["final_norm"]["scale"][pp - 1]}
    if "head" in params:
        out["head"] = {"w": gstack["head"]["w"][pp - 1]}
    return out


# ---------------------------------------------------------------------------
# SPMD stage apply (union slots, masked) — the executor's layer stack
# ---------------------------------------------------------------------------

def _slot_apply(p: PyTree, spec: ModelSpec, opts: ModelOptions,
                x: jnp.ndarray, positions: jnp.ndarray, mask: jnp.ndarray,
                moe_flag: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One union layer slot.  ``mask`` (scalar f32) turns pad slots into the
    identity; ``moe_flag`` selects the MoE vs dense-MLP branch when the model
    mixes kinds (only the selected branch receives gradient)."""
    gemma = spec.name.startswith("gemma")
    window = spec.sliding_window
    h1 = rmsnorm(p["ln1"], x, spec.norm_eps, gemma_style=gemma)
    if spec.attention == AttentionKind.MLA:
        mix = M.mla_forward(p["attn"], spec, h1, positions,
                            impl=opts.attn_impl)
    else:
        mix = A.gqa_forward(p["attn"], spec, h1, positions,
                            impl=opts.attn_impl, window=window)
    x = x + mix * mask.astype(x.dtype)
    h2 = rmsnorm(p["ln2"], x, spec.norm_eps, gemma_style=gemma)
    aux = jnp.zeros((), jnp.float32)
    has_mlp, has_moe = "mlp" in p, "moe" in p
    if has_moe:
        out = moe_forward(p["moe"], spec, h2,
                          capacity_factor=opts.capacity_factor,
                          router_impl=opts.router_impl)
        sel = moe_flag.astype(x.dtype)
        delta = out.y * sel
        if has_mlp:
            delta = delta + mlp_apply(p["mlp"], spec, h2) * (1 - sel)
        aux = out.aux_loss * moe_flag * mask
    elif has_mlp:
        delta = mlp_apply(p["mlp"], spec, h2)
    else:
        delta = jnp.zeros_like(x)
    x = x + delta * mask.astype(x.dtype)
    return x, aux


def pipeline_stage_apply(layers_p: PyTree, spec: ModelSpec,
                         opts: ModelOptions, x: jnp.ndarray,
                         positions: jnp.ndarray, mask: jnp.ndarray,
                         moe_flag: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan this stage's l_max union slots.  ``layers_p`` leaves are
    (l_max, ...); ``mask``/``moe_flag`` are (l_max,)."""

    def body(carry, inp):
        xc, aux = carry
        p_slot, m, f = inp
        xc, a = _slot_apply(p_slot, spec, opts, xc, positions, m, f)
        return (xc, aux + a), None

    body = _remat(body, opts.recompute)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (layers_p, mask, moe_flag))
    return x, aux
