"""Pipeline-parallel partitioning of a Model into per-rank layer chunks.

The layer→chunk assignment is ``core.params.pp_stage_layers`` — the exact
split behind the paper's Table 4 — so the runtime executor, the per-stage
dry-run probes and the analytical model (``estimate_memory(stage=...)``,
``table4_stages``) can never disagree about which layers live where.  With a
pipeline *schedule* (``core.schedules``) a rank may hold several chunks:
plain ``1f1b`` keeps one contiguous stage per rank, Megatron-style
``interleaved`` assigns ``v`` virtual stages (rank r holds model chunks
``{r, pp+r, …}``), and ``dualpipe`` assigns each rank two mirrored stages
``(r, pp-1-r)`` with every stage *duplicated* across two ranks (DualPipe's
2× parameter cost).

Two views of the same partition are provided:

* **Heterogeneous chunk slices** (``stage_params_slice`` /
  ``chunk_params_slice`` + ``make_stage_fn`` / ``make_chunk_fn``): a chunk's
  true parameter subtree (embedding only with model chunk 0, final norm /
  head only with the last, its own contiguous dense/MoE sub-stacks) and a
  forward for exactly those layers.  Used by the dry-run to lower/compile
  each rank as its own program and read XLA's per-rank ``memory_analysis``
  — the numbers compared against ``estimate_memory(spec, cfg, stage=r,
  schedule=...)``.

* **Chunk-stacked (SPMD) layout** (``stack_pipeline_params`` /
  ``unstack_pipeline_grads`` + ``pipeline_stage_apply``): every layer leaf
  gains leading ``(pp, n_chunks, l_max)`` dims with the ``pp`` dim sharded
  over the ``pipe`` mesh axis, chunk layer stacks padded to the widest chunk
  (masked identity slots) and a *union* slot structure (a slot carries both
  the dense-MLP and MoE subtrees when the model mixes kinds; a per-slot
  flag selects).  Embedding / final-norm / head keep one row per rank, zero
  except on ranks whose chunks own them.  This is what the schedule-driven
  executor (``train.pipeline_loop``) runs under ``shard_map`` — one
  program, rank identity = ``lax.axis_index('pipe')``, the active chunk per
  tick read from the schedule's static tables.

The stacked layout trades memory for SPMD uniformity (padded slots, the
unused half of mixed dense/MoE slots, zero embed rows on interior ranks);
the per-rank dry-run path has no such padding, so memory validation always
uses the heterogeneous view.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.notation import AttentionKind, FamilyKind, ModelSpec
from repro.core.params import pp_stage_layers
from repro.parallel.axes import logical_constraint
from . import attention as A
from . import backend as B
from . import mla as M
from .layers import embed_apply, mlp_apply
from .moe import moe_forward
from .transformer import ModelOptions, _remat, stack_apply

PyTree = Any


def check_pipeline_supported(spec: ModelSpec) -> None:
    """The pipeline runtime covers the paper's training families: decoder-only
    dense and MoE transformers (MLA or GQA/MHA attention).  Recurrent, enc-dec
    and stub-frontend families keep the pp=1 path."""
    if spec.ssm is not None:
        raise NotImplementedError("pipeline runtime: SSM/hybrid unsupported")
    if spec.encoder is not None:
        raise NotImplementedError("pipeline runtime: enc-dec unsupported")
    if spec.family == FamilyKind.VLM:
        raise NotImplementedError("pipeline runtime: VLM frontend unsupported")
    if spec.attention == AttentionKind.NONE:
        raise NotImplementedError("pipeline runtime: attention-free unsupported")


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """Layer→stage assignment plus the index/mask arrays both runtime views
    derive from it.  All arrays are numpy (static schedule data)."""

    pp: int
    n_layers: int
    n_dense: int                      # dense layers are global ids [0, n_dense)
    stages: Tuple[Tuple[int, ...], ...]
    l_max: int                        # widest stage (slot count of the SPMD view)
    idx: np.ndarray                   # (pp, l_max) global layer id; pads repeat
    mask: np.ndarray                  # (pp, l_max) f32: 1 real slot, 0 pad
    moe_flag: np.ndarray              # (pp, l_max) f32: 1 MoE layer, 0 dense
    stage_of: np.ndarray              # (n_layers,) stage owning each layer
    slot_of: np.ndarray               # (n_layers,) slot within that stage


def partition(spec: ModelSpec, pp: int) -> StagePartition:
    if not 1 <= pp <= spec.n_layers:
        raise ValueError(f"pp={pp} must be in [1, n_layers={spec.n_layers}]")
    stages = tuple(tuple(ls) for ls in pp_stage_layers(spec.n_layers, pp))
    n_dense = spec.n_layers - spec.n_moe_layers()
    l_max = max(len(ls) for ls in stages)
    idx = np.zeros((pp, l_max), np.int32)
    mask = np.zeros((pp, l_max), np.float32)
    moe_flag = np.zeros((pp, l_max), np.float32)
    stage_of = np.zeros(spec.n_layers, np.int32)
    slot_of = np.zeros(spec.n_layers, np.int32)
    for i, ls in enumerate(stages):
        for j in range(l_max):
            l = ls[j] if j < len(ls) else ls[-1]      # pads repeat a real layer
            idx[i, j] = l
            if j < len(ls):
                mask[i, j] = 1.0
                moe_flag[i, j] = float(l >= n_dense)
                stage_of[l] = i
                slot_of[l] = j
    return StagePartition(pp=pp, n_layers=spec.n_layers, n_dense=n_dense,
                          stages=stages, l_max=l_max, idx=idx, mask=mask,
                          moe_flag=moe_flag, stage_of=stage_of,
                          slot_of=slot_of)


@dataclasses.dataclass(frozen=True)
class ChunkedPartition:
    """Schedule-aware layer→(rank, chunk) assignment plus the index/mask
    arrays the chunk-stacked SPMD layout derives from it.  All arrays are
    numpy (static schedule data).  ``occurrences[l]`` lists every
    (rank, chunk, slot) holding global layer ``l`` — exactly one entry per
    layer except under dualpipe, where every layer lives on two ranks."""

    pp: int
    n_chunks: int                     # v, local chunks per rank
    n_stages: int                     # model chunks overall (pp*v or pp)
    n_layers: int
    n_dense: int
    schedule: str
    chunks: Tuple[Tuple[Tuple[int, ...], ...], ...]   # (pp, v) layer tuples
    placement: Tuple[Tuple[int, ...], ...]            # (pp, v) model chunk id
    l_max: int                        # widest chunk (slot count per chunk)
    idx: np.ndarray                   # (pp, v, l_max) global layer id
    mask: np.ndarray                  # (pp, v, l_max) f32: 1 real, 0 pad
    moe_flag: np.ndarray              # (pp, v, l_max) f32
    first_flag: np.ndarray            # (pp, v) f32: chunk is model chunk 0
    last_flag: np.ndarray             # (pp, v) f32: chunk is the last
    occurrences: Tuple[Tuple[Tuple[int, int, int], ...], ...]


def chunked_partition(spec: ModelSpec, pp: int, *, schedule: str = "1f1b",
                      n_chunks: int = 1) -> ChunkedPartition:
    """Partition for a pipeline schedule: model split into
    ``core.n_model_chunks`` contiguous pieces (same front-loaded Table-4
    rule as plain PP), placed per ``core.schedule_placement``."""
    from repro.core.activations import rank_chunk_layers
    from repro.core.schedules import (norm_chunks, n_model_chunks,
                                      schedule_placement)
    check_pipeline_supported(spec)
    v = norm_chunks(schedule, n_chunks)
    g = n_model_chunks(schedule, pp, v)
    if not 1 <= g <= spec.n_layers:
        raise ValueError(f"{g} model chunks need n_layers >= {g} "
                         f"(got {spec.n_layers})")
    chunks = rank_chunk_layers(spec, pp, schedule=schedule, n_chunks=v)
    placement = schedule_placement(schedule, pp, v)
    n_dense = spec.n_layers - spec.n_moe_layers()
    l_max = max(len(ls) for row in chunks for ls in row)
    idx = np.zeros((pp, v, l_max), np.int32)
    mask = np.zeros((pp, v, l_max), np.float32)
    moe_flag = np.zeros((pp, v, l_max), np.float32)
    first = np.zeros((pp, v), np.float32)
    last = np.zeros((pp, v), np.float32)
    occ: Dict[int, list] = {l: [] for l in range(spec.n_layers)}
    for r in range(pp):
        for c in range(v):
            ls = chunks[r][c]
            first[r, c] = float(placement[r][c] == 0)
            last[r, c] = float(placement[r][c] == g - 1)
            for j in range(l_max):
                l = ls[j] if j < len(ls) else ls[-1]  # pads repeat a layer
                idx[r, c, j] = l
                if j < len(ls):
                    mask[r, c, j] = 1.0
                    moe_flag[r, c, j] = float(l >= n_dense)
                    occ[l].append((r, c, j))
    return ChunkedPartition(
        pp=pp, n_chunks=v, n_stages=g, n_layers=spec.n_layers,
        n_dense=n_dense, schedule=schedule, chunks=chunks,
        placement=placement, l_max=l_max, idx=idx, mask=mask,
        moe_flag=moe_flag, first_flag=first, last_flag=last,
        occurrences=tuple(tuple(occ[l]) for l in range(spec.n_layers)))


# ---------------------------------------------------------------------------
# Heterogeneous view: true per-stage parameter subtrees + per-stage forward
# ---------------------------------------------------------------------------

def chunk_params_slice(params: PyTree, spec: ModelSpec,
                       layers: Tuple[int, ...], *, with_embed: bool,
                       with_head: bool) -> PyTree:
    """One contiguous layer chunk's parameters in the Model layout (keys
    kept so the §3 TP/ZeRO sharding rules in ``parallel.sharding`` apply
    unchanged).  ``with_embed``/``with_head`` attach the embedding / final
    norm + output head — owned by the first / last *model* chunk, which
    under multi-chunk schedules is a property of the chunk, not the rank."""
    check_pipeline_supported(spec)
    lo, hi = layers[0], layers[-1] + 1
    if list(layers) != list(range(lo, hi)):
        raise ValueError(f"chunk layers must be contiguous, got {layers}")
    nd = spec.n_layers - spec.n_moe_layers()
    out: Dict[str, Any] = {}
    if with_embed:
        out["embed"] = params["embed"]
    d_lo, d_hi = lo, min(hi, nd)
    if d_hi > d_lo:
        out["dense_layers"] = jax.tree.map(lambda a: a[d_lo:d_hi],
                                           params["dense_layers"])
    m_lo, m_hi = max(lo, nd) - nd, hi - nd
    if m_hi > max(m_lo, 0):
        out["moe_layers"] = jax.tree.map(lambda a: a[m_lo:m_hi],
                                         params["moe_layers"])
    if with_head:
        out["final_norm"] = params["final_norm"]
        if spec.tie_embeddings:
            out["embed"] = params["embed"]
        elif "head" in params:
            out["head"] = params["head"]
    return out


def stage_params_slice(params: PyTree, spec: ModelSpec, pp: int,
                       stage: int) -> PyTree:
    """Plain-1F1B view: stage ``stage``'s parameters (embedding on stage 0,
    final norm / head on the last stage)."""
    part = partition(spec, pp)
    return chunk_params_slice(params, spec, part.stages[stage],
                              with_embed=stage == 0, with_head=stage == pp - 1)


def make_chunk_fn(spec: ModelSpec, opts: ModelOptions,
                  layers: Tuple[int, ...], *, is_first: bool, is_last: bool):
    """fn(chunk_params, x, tokens) -> (out, aux) for one contiguous layer
    chunk.

    The first model chunk embeds ``tokens`` (``x`` is ignored); interior
    chunks transform the boundary activation ``x``; the last chunk returns
    vocab logits (callers compute the loss — the executor and the dry-run
    probes need different reductions).  Composing every chunk in model
    order is exactly ``Model.forward`` for the supported families.
    """
    check_pipeline_supported(spec)
    nd = spec.n_layers - spec.n_moe_layers()
    gemma = spec.name.startswith("gemma")
    window = spec.sliding_window

    def fn(chunk_params: PyTree, x: Optional[jnp.ndarray],
           tokens: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if is_first:
            x = embed_apply(chunk_params["embed"], tokens,
                            scale_by_dim=gemma, h=spec.h)
        b, s = x.shape[0], x.shape[1]
        x = logical_constraint(x, ("batch", "seq", "embed"))
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        aux = jnp.zeros((), jnp.float32)
        if "dense_layers" in chunk_params:
            x, a = stack_apply(chunk_params["dense_layers"], spec, opts, x,
                               positions, False, window=window)
            aux = aux + a
        if "moe_layers" in chunk_params:
            x, a = stack_apply(chunk_params["moe_layers"], spec, opts, x,
                               positions, True, window=window)
            aux = aux + a
        if is_last:
            x = B.rmsnorm(chunk_params["final_norm"], x, spec.norm_eps,
                          gemma_style=gemma, backend=B.resolve_backend(opts))
            if spec.tie_embeddings:
                logits = x @ chunk_params["embed"]["w"].T
            else:
                logits = x @ chunk_params["head"]["w"]
            logits = logical_constraint(logits, ("batch", "seq", "vocab"))
            return logits, aux
        return x, aux

    return fn


def make_stage_fn(spec: ModelSpec, opts: ModelOptions, pp: int, stage: int):
    """Plain-1F1B view of :func:`make_chunk_fn`: the forward of Table-4
    stage ``stage``.  With pp=1 this is exactly ``Model.forward``."""
    part = partition(spec, pp)
    return make_chunk_fn(spec, opts, part.stages[stage],
                         is_first=stage == 0, is_last=stage == pp - 1)


# ---------------------------------------------------------------------------
# Stage-stacked (SPMD) view: leading pp dim for shard_map over 'pipe'
# ---------------------------------------------------------------------------

def _take_layers(leaf: jnp.ndarray, index: np.ndarray) -> jnp.ndarray:
    flat = jnp.take(leaf, jnp.asarray(index.reshape(-1)), axis=0)
    return flat.reshape(index.shape + leaf.shape[1:])


def stack_pipeline_params(params: PyTree, spec: ModelSpec, pp: int, *,
                          schedule: str = "1f1b",
                          n_chunks: int = 1) -> PyTree:
    """Model params → chunk-stacked layout for the schedule.

    layers: union slot structure, leaves (pp, n_chunks, l_max, ...); pad
    slots repeat a real layer of the chunk (masked to identity at apply
    time) and the unused kind of a mixed dense/MoE slot holds a
    clipped-gather copy (never selected, so it receives exactly zero
    gradient).  embed/final_norm/head: (pp, ...) rows, zero except on ranks
    whose chunks own them (under dualpipe rank 0 and rank pp-1 each own an
    embedding *and* a head copy).
    """
    part = chunked_partition(spec, pp, schedule=schedule, n_chunks=n_chunks)
    nd = part.n_dense
    dense = params.get("dense_layers") or {}
    moe = params.get("moe_layers") or {}
    idx = part.idx
    idx_d = np.clip(idx, 0, max(nd - 1, 0))
    idx_m = np.clip(idx - nd, 0, max(part.n_layers - nd - 1, 0))

    layers: Dict[str, Any] = {}
    for k in dense:
        if k in moe:
            layers[k] = jax.tree.map(
                lambda a, b: _take_layers(jnp.concatenate([a, b], axis=0), idx),
                dense[k], moe[k])
        else:
            layers[k] = jax.tree.map(lambda a: _take_layers(a, idx_d), dense[k])
    for k in moe:
        if k not in dense:
            layers[k] = jax.tree.map(lambda a: _take_layers(a, idx_m), moe[k])

    has_first = part.first_flag.max(axis=1) > 0        # (pp,) rank owns chunk 0
    has_last = part.last_flag.max(axis=1) > 0
    emb = params["embed"]["w"]
    emb_st = jnp.zeros((pp,) + emb.shape, emb.dtype)
    fin = params["final_norm"]["scale"]
    fin_st = jnp.zeros((pp,) + fin.shape, fin.dtype)
    hd = params.get("head", {}).get("w")
    hd_st = jnp.zeros((pp,) + hd.shape, hd.dtype) if hd is not None else None
    for r in range(pp):
        if has_first[r] or (spec.tie_embeddings and has_last[r]):
            emb_st = emb_st.at[r].set(emb)
        if has_last[r]:
            fin_st = fin_st.at[r].set(fin)
            if hd_st is not None:
                hd_st = hd_st.at[r].set(hd)
    out: Dict[str, Any] = {"layers": layers,
                           "embed": {"w": emb_st},
                           "final_norm": {"scale": fin_st}}
    if hd_st is not None:
        out["head"] = {"w": hd_st}
    return out


def unstack_pipeline_grads(gstack: PyTree, params: PyTree, spec: ModelSpec,
                           pp: int, *, schedule: str = "1f1b",
                           n_chunks: int = 1) -> PyTree:
    """Chunk-stacked gradient pytree → the Model parameter layout.

    Every global layer's gradient is summed over its (rank, chunk, slot)
    occurrences — one under 1f1b/interleaved, two under dualpipe (both
    parameter copies saw different microbatches).  embed/final_norm/head
    rows are summed across ranks (rows on non-owning ranks are exactly
    zero: their outputs are never selected, so no gradient flows there)."""
    part = chunked_partition(spec, pp, schedule=schedule, n_chunks=n_chunks)
    nd = part.n_dense
    occ = part.occurrences
    r_idx = np.asarray([[o[0] for o in occ[l]] for l in range(part.n_layers)])
    c_idx = np.asarray([[o[1] for o in occ[l]] for l in range(part.n_layers)])
    s_idx = np.asarray([[o[2] for o in occ[l]] for l in range(part.n_layers)])

    def gather(leaf: jnp.ndarray) -> jnp.ndarray:
        # (n_layers, n_occurrences, ...) summed over occurrences
        return leaf[r_idx, c_idx, s_idx].sum(axis=1)

    dense = params.get("dense_layers") or {}
    moe = params.get("moe_layers") or {}
    out: Dict[str, Any] = {"dense_layers": {}, "moe_layers": {}}
    for k in dense:
        out["dense_layers"][k] = jax.tree.map(
            lambda a: gather(a)[:nd], gstack["layers"][k])
    for k in moe:
        out["moe_layers"][k] = jax.tree.map(
            lambda a: gather(a)[nd:], gstack["layers"][k])
    out["embed"] = {"w": gstack["embed"]["w"].sum(axis=0)}
    out["final_norm"] = {"scale": gstack["final_norm"]["scale"].sum(axis=0)}
    if "head" in params:
        out["head"] = {"w": gstack["head"]["w"].sum(axis=0)}
    return out


# ---------------------------------------------------------------------------
# SPMD stage apply (union slots, masked) — the executor's layer stack
# ---------------------------------------------------------------------------

def _slot_apply(p: PyTree, spec: ModelSpec, opts: ModelOptions,
                x: jnp.ndarray, positions: jnp.ndarray, mask: jnp.ndarray,
                moe_flag: jnp.ndarray, tp_axis: Optional[str] = None,
                sp: bool = False, ep: int = 1
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One union layer slot.  ``mask`` (scalar f32) turns pad slots into the
    identity; ``moe_flag`` selects the MoE vs dense-MLP branch when the model
    mixes kinds (only the selected branch receives gradient).

    ``tp_axis`` (the executor's 'model' mesh axis) switches on manual
    Megatron TP: ``spec`` must then be the TP-local view
    (``parallel.tp.tp_local_spec``) matching 'model'-sharded weights, and
    every block is bracketed by the f/g operators of ``parallel.tp`` —
    ``copy_to_tp`` where the replicated residual enters sharded compute,
    ``reduce_from_tp`` where partial block outputs rejoin it.

    ``sp`` (Megatron sequence parallelism, degree = tp) replaces the f/g
    pair with ğ and its dual: ``x`` arrives *seq-sharded* across
    ``tp_axis``, the norms run on the shard, ``gather_from_sp`` assembles
    the full sequence on entry to each TP region and ``scatter_to_sp``
    reduce-scatters block outputs back onto the shard.  The sharded token
    dim is always the second-to-last (the residual's seq, the MoE dispatch
    buffer's capacity, flat-token rows), hence ``ndim - 2`` below.

    ``ep`` (> 1 ⇒ == tp) switches the MoE branch to true expert
    parallelism over ``tp_axis``: routed expert weights arrive sharded on
    their *expert* dim and the dispatch is ``moe_forward``'s all-to-all
    token exchange instead of the replicated ETP buffer — ``tp_f``/``tp_g``
    then only bracket the shared expert (still ETP-sharded on its ff
    dim)."""
    from repro.parallel.tp import (copy_to_tp, gather_from_sp,
                                   reduce_from_tp, scatter_to_sp)
    gemma = spec.name.startswith("gemma")
    window = spec.sliding_window
    sp = bool(sp and tp_axis)
    if sp:
        tpf = lambda t: gather_from_sp(t, tp_axis, t.ndim - 2)
        tpg = lambda t: scatter_to_sp(t, tp_axis, t.ndim - 2)
    else:
        tpf = (lambda t: copy_to_tp(t, tp_axis)) if tp_axis else (lambda t: t)
        tpg = (lambda t: reduce_from_tp(t, tp_axis)) if tp_axis \
            else (lambda t: t)
    # ONE backend resolution per slot: the pallas kernels run on the
    # pre-sharded operands the f/g/ğ operators deliver — flash sees the
    # TP-local n_h/tp heads on the gathered full sequence, grouped_mlp the
    # (E/ep, C, h) local dispatch buffer (see models.backend's contract)
    backend = B.resolve_backend(opts)
    is_mla = spec.attention == AttentionKind.MLA
    attn_impl = B.resolve_attn_impl(opts, causal=True,
                                    window=None if is_mla else window)
    h1 = B.rmsnorm(p["ln1"], x, spec.norm_eps, gemma_style=gemma,
                   backend=backend)
    if is_mla:
        # MLA's replicated down-projections run redundantly on every shard;
        # the f operator sits on the compressed latents inside _towers.
        # Under SP the towers consume the *gathered* full-sequence view
        # (tpf(h1)) — the latents stay full-length on every shard, which is
        # why the paper's 2bs(d_cq+d_c) terms carry no /sp divisor — and
        # the latents must NOT carry copy_to_tp: the entry ğ's
        # reduce-scatter backward already sums the per-shard partial
        # cotangents, so a psum-bwd on the latents would double-count
        # (tp× gradients).  The tower weight grads are then head-partial
        # per shard; the executor's post-loop 'model' psum completes them
        # (train.pipeline_loop).
        lat_f = None if (sp or not tp_axis) else tpf
        mix = M.mla_forward(p["attn"], spec, tpf(h1) if sp else h1,
                            positions, impl=attn_impl, tpf=lat_f,
                            backend=backend)
    else:
        mix = A.gqa_forward(p["attn"], spec, tpf(h1), positions,
                            impl=attn_impl, window=window)
    mix = tpg(mix)
    x = x + mix * mask.astype(x.dtype)
    h2 = B.rmsnorm(p["ln2"], x, spec.norm_eps, gemma_style=gemma,
                   backend=backend)
    aux = jnp.zeros((), jnp.float32)
    has_mlp, has_moe = "mlp" in p, "moe" in p
    if has_moe:
        out = moe_forward(p["moe"], spec, h2,
                          capacity_factor=opts.capacity_factor,
                          router_impl=opts.router_impl,
                          tp_f=tpf if tp_axis else None,
                          tp_g=tpg if tp_axis else None,
                          sp_axis=tp_axis if sp else None,
                          ep=ep, ep_axis=tp_axis if ep > 1 else None,
                          backend=backend)
        sel = moe_flag.astype(x.dtype)
        delta = out.y * sel
        if has_mlp:
            delta = delta + tpg(mlp_apply(p["mlp"], spec, tpf(h2))) * (1 - sel)
        aux = out.aux_loss * moe_flag * mask
    elif has_mlp:
        delta = tpg(mlp_apply(p["mlp"], spec, tpf(h2)))
    else:
        delta = jnp.zeros_like(x)
    x = x + delta * mask.astype(x.dtype)
    return x, aux


def pipeline_stage_apply(layers_p: PyTree, spec: ModelSpec,
                         opts: ModelOptions, x: jnp.ndarray,
                         positions: jnp.ndarray, mask: jnp.ndarray,
                         moe_flag: jnp.ndarray,
                         tp_axis: Optional[str] = None,
                         sp: bool = False, ep: int = 1,
                         remat: bool = True
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan this stage's l_max union slots.  ``layers_p`` leaves are
    (l_max, ...); ``mask``/``moe_flag`` are (l_max,).  With ``tp_axis`` the
    slots run manual TP; with ``sp`` additionally Megatron sequence
    parallelism — ``x`` is then the seq-sharded residual; with ``ep`` the
    MoE slots dispatch expert-parallel over the same axis (see
    ``_slot_apply``).

    ``remat=False`` bypasses ``opts.recompute`` for this call: a vjp through
    the stage then stores the slot internals instead of recomputing them —
    the zb1p executor's B tick uses this (it runs the full vjp once, with
    no recompute replay, and parks the weight grads in the fp32 pending-dW
    stash for the deferred W flush; the replay it skips is exactly the
    compute zero-bubble trades stash memory for)."""

    def body(carry, inp):
        xc, aux = carry
        p_slot, m, f = inp
        xc, a = _slot_apply(p_slot, spec, opts, xc, positions, m, f, tp_axis,
                            sp, ep)
        return (xc, aux + a), None

    if remat:
        body = _remat(body, opts.recompute)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (layers_p, mask, moe_flag))
    return x, aux
