"""Shared building blocks: RMSNorm, RoPE (incl. an M-RoPE reduction),
gated MLPs, embeddings.  Pure functions over explicit param dicts.

Dtype discipline (paper Table 7): weights/activations bf16, reductions
(norm statistics, softmax, loss) in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.notation import MlpKind, ModelSpec

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.bfloat16, scale: Optional[float] = None) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(h: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.ones((h,), dtype)}

def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6,
            gemma_style: bool = False) -> jnp.ndarray:
    """Gemma parameterises the gain as (1 + scale); others as scale."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    g = p["scale"].astype(jnp.float32)
    g = 1.0 + g if gemma_style else g
    return (y * g).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, n_heads, d); positions: (..., seq).

    M-RoPE note (Qwen2-VL): multimodal rotary splits the head dim into
    temporal/height/width sections with separate position ids.  With the
    stubbed vision frontend all modalities collapse to the temporal stream,
    so M-RoPE reduces to 1-D RoPE over the interleaved token sequence —
    recorded in DESIGN.md as a frontend-stub consequence.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, d/2)
    cos = jnp.cos(angles)[..., None, :]                        # broadcast heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, spec: ModelSpec, d_ff: int,
             dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if spec.mlp in (MlpKind.SWIGLU, MlpKind.GEGLU):
        return {"gate": dense_init(k1, (spec.h, d_ff), dtype),
                "up": dense_init(k2, (spec.h, d_ff), dtype),
                "down": dense_init(k3, (d_ff, spec.h), dtype)}
    return {"fc1": dense_init(k1, (spec.h, d_ff), dtype),
            "fc2": dense_init(k2, (d_ff, spec.h), dtype)}

def mlp_apply(p: Params, spec: ModelSpec, x: jnp.ndarray) -> jnp.ndarray:
    if spec.mlp == MlpKind.SWIGLU:
        a = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
        return a @ p["down"]
    if spec.mlp == MlpKind.GEGLU:
        a = jax.nn.gelu(x @ p["gate"], approximate=True) * (x @ p["up"])
        return a @ p["down"]
    return jax.nn.gelu(x @ p["fc1"], approximate=True) @ p["fc2"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, h: int, dtype=jnp.bfloat16) -> Params:
    # ~N(0, h^-1): keeps tied-embedding logits O(1) at init
    return {"w": dense_init(key, (vocab, h), dtype, scale=h ** -0.5)}

def embed_apply(p: Params, tokens: jnp.ndarray, scale_by_dim: bool = False,
                h: int = 0) -> jnp.ndarray:
    x = jnp.take(p["w"], tokens, axis=0)
    if scale_by_dim:  # gemma multiplies embeddings by sqrt(h)
        x = x * jnp.asarray(h ** 0.5, x.dtype)
    return x

def head_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Project to vocab logits in fp32 (loss numerics)."""
    return (x @ p["w"]).astype(jnp.float32)

def head_init(key: jax.Array, h: int, vocab: int, dtype=jnp.bfloat16) -> Params:
    return {"w": dense_init(key, (h, vocab), dtype)}
