import os
if "XLA_FLAGS" not in os.environ and os.environ.get("REPRO_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FAKE_DEVICES"])

"""Training launcher: ``--arch <id>`` on the local device set (or a debug
mesh), with the paper's knobs exposed.

  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 50 --batch 8 --seq 256 --zero os+g --recompute full

On a real TPU pod this process runs once per host; jax.distributed picks up
the cluster topology and ``make_production_mesh`` lays the global mesh.
Here (CPU container) it drives the same code on small meshes; set
REPRO_FAKE_DEVICES=8 to exercise multi-device sharding paths.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_spec
from repro.core.parallel_config import RecomputePolicy, ZeROStage
from repro.data.synthetic import config_for, make_batch
from repro.launch.specs import batch_shardings
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.optim.adamw import AdamWConfig, init_train_state
from repro.parallel.axes import axis_rules
from repro.parallel.sharding import state_shardings
from repro.train.loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--zero", default="os+g",
                    choices=[z.value for z in ZeROStage])
    ap.add_argument("--recompute", default="none",
                    choices=[r.value for r in RecomputePolicy])
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-axis size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    spec = get_spec(args.arch, smoke=args.smoke)
    opts = ModelOptions(attn_impl=args.attn,
                        recompute=RecomputePolicy(args.recompute))
    model = build_model(spec, opts)

    n_dev = jax.device_count()
    data_ax = args.data_axis or (n_dev // args.model_axis)
    mesh = jax.make_mesh((data_ax, args.model_axis), ("data", "model"))
    print(f"arch={spec.name} devices={n_dev} mesh=({data_ax},{args.model_axis}) "
          f"zero={args.zero} ac={args.recompute}")

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M "
          f"(analytic {spec.total_params()/1e6:.1f}M)")
    state = init_train_state(params)
    abstract_state = jax.eval_shape(lambda: state)
    st_sh = state_shardings(abstract_state, mesh, ZeROStage(args.zero))
    step_fn = make_train_step(model, TrainConfig(
        n_micro=args.n_micro, adamw=AdamWConfig(lr=args.lr)))

    data_cfg = config_for(spec, args.batch, args.seq)
    b0 = make_batch(data_cfg, 0)
    b_sh = batch_shardings(jax.eval_shape(lambda: b0), mesh)

    with axis_rules(mesh):
        fn = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=0)
        state = jax.device_put(state, st_sh)
        t0 = time.perf_counter()
        for i in range(args.steps):
            batch = jax.device_put(make_batch(data_cfg, i), b_sh)
            state, metrics = fn(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:>5}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"{time.perf_counter()-t0:.0f}s")
    print("done")


if __name__ == "__main__":
    main()
