"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — the roofline
table's mesh.  Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data",
"model"); the "pod" axis extends data parallelism across the ICI/DCN
boundary (DP-major placement, matching the paper's DP×EDP grouping where
ZeRO shards span data×pod).

Defined as FUNCTIONS so importing this module never initialises jax device
state (the dry-run must set XLA_FLAGS before first jax use).
"""

from __future__ import annotations

import jax

from repro.core.parallel_config import ParallelConfig, ZeROStage


def make_production_mesh(*, multi_pod: bool = False, shape=None, pp: int = 1):
    """Default single-pod (16,16) / multi-pod (2,16,16).  ``shape`` overrides
    the per-pod grid, e.g. (32, 8) — a decode-shaped mesh whose model axis
    divides small KV-head counts (§Perf hillclimb 3); total chips must stay
    256/pod.

    ``pp`` > 1 carves a leading ``pipe`` axis out of the data axis (the
    paper's world = DP·TP·PP tiling: PP groups are data-major so ZeRO's
    DP/EDP sync stays within a stage): (16,16) with pp=4 becomes the
    (4, 4, 16) mesh ('pipe', 'data', 'model')."""
    data, model = tuple(shape) if shape is not None else (16, 16)
    if pp > 1:
        if data % pp:
            raise ValueError(f"pp={pp} must divide the data axis ({data})")
        grid, axes = (pp, data // pp, model), ("pipe", "data", "model")
    else:
        grid, axes = (data, model), ("data", "model")
    if multi_pod:
        grid, axes = (2,) + grid, ("pod",) + axes
    return jax.make_mesh(grid, axes)


def make_debug_mesh(model: int = 1, data: int = 1, pipe: int = 1):
    """Tiny mesh over however many (possibly fake) local devices exist."""
    if pipe > 1:
        return jax.make_mesh((pipe, data, model), ("pipe", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def parallel_config_for_mesh(mesh, *, spec=None, zero: ZeROStage = ZeROStage.OS_G,
                             micro_batch: int = 1, seq_len: int = 4096,
                             recompute="none") -> ParallelConfig:
    """Analytical-model view of a mesh: TP/EP live on the 'model' axis, DP on
    data(+pod).  Used to compare estimate_memory() with XLA's
    memory_analysis() for the same configuration."""
    from repro.core.parallel_config import RecomputePolicy
    model_ax = mesh.shape.get("model", 1)
    data_ax = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    pp = mesh.shape.get("pipe", 1)
    n_exp = spec.moe.n_routed if (spec is not None and spec.is_moe) else None
    ep = min(model_ax, n_exp) if n_exp else 1
    rc = RecomputePolicy(recompute) if isinstance(recompute, str) else recompute
    return ParallelConfig(dp=data_ax, tp=model_ax, pp=pp, ep=ep, etp=1,
                          sp=True, zero=zero, recompute=rc,
                          micro_batch=micro_batch, seq_len=seq_len)
