import os
if "XLA_FLAGS" not in os.environ and os.environ.get("REPRO_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FAKE_DEVICES"])

"""Serving launcher: batched decode for ``--arch <id>``.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 8 --new-tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_spec
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.serving import ServeConfig, serve_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    spec = get_spec(args.arch, smoke=args.smoke)
    model = build_model(spec, ModelOptions())
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, spec.vocab)
    enc_out = None
    if spec.encoder is not None:
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, spec.encoder.n_ctx, spec.h), jnp.bfloat16) * 0.02
        enc_out = model._encode(params, frames)

    t0 = time.perf_counter()
    out = serve_requests(model, params, prompts,
                         ServeConfig(max_new_tokens=args.new_tokens,
                                     temperature=args.temperature),
                         cache_len=args.cache_len, enc_out=enc_out)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"arch={spec.name} generated {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s incl. prefill+compile)")
    for i in range(min(args.batch, 2)):
        print(f"  seq{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
