import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) on placeholder devices; record memory_analysis / cost_analysis /
collective bytes for EXPERIMENTS.md §Dry-run and the §Roofline table.

The two lines above MUST precede any other import (jax locks the device
count at first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3 --shape train_4k \
      --zero os+g --recompute full --attn chunked --n-micro 16
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1_5b --shape train_4k \
      --pp 4 --n-micro 8 --schedule dualpipe
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1_5b --shape train_4k \
      --pp 2 --tp 2 --zero os --n-micro 8

Arguments (see ``main()``): ``--arch``/``--shape`` or ``--all`` select the
combos; ``--zero``, ``--recompute``, ``--attn``, ``--n-micro``,
``--capacity-factor``, ``--moe-impl`` configure the lowered program;
``--mesh-shape``/``--multi-pod`` the fake device grid, ``--tp N`` overrides
just its 'model' axis (so ``--pp --tp --zero`` compose into joint 3D+ZeRO
probes on small fake meshes); ``--sp N`` (N = the TP degree) additionally
shards the probe's boundary/residual sequence dims over 'model' and sets
the analytic sp divisor — the measurement side of the executor's Megatron
sequence parallelism; ``--ep N`` (MoE archs, N = 1 or the TP degree) pins
the expert placement on both sides — N>1 shards expert weights on their
expert dim over 'model' (the executor's EP layout) and sets the analytic
ep divisor, N=1 pins the ETP layout — so an ``__ep1``/``__ep2`` artifact
pair measures the (E/ep, C, h) dispatch-buffer shrink.  With ``--pp N``
(> 1) each pipeline rank is
compiled as its own program holding the schedule's in-flight microbatch
counts (``--schedule {1f1b,interleaved,dualpipe,zb1p}``, ``--pp-chunks`` virtual
stages per rank) next to ``estimate_memory(stage=r, schedule=...)`` — the
measurement side of ``docs/pipeline-schedules.md``.

Artifacts: one JSON per combo in ``benchmarks/artifacts/dryrun/<tag>.json``
(tag =
arch__shape__mesh[__ppN[__<schedule><v>]][__z<zero>][__sp<N>][__ep<N>][suffix];
the mesh component encodes tp, the ``__z`` component appears for
non-default ``--zero``, ``__sp`` for ``--sp`` > 1, ``__ep`` whenever
``--ep`` is explicit) with status,
lower/compile wall-times, ``memory_analysis`` fields, flops/bytes from
``cost_analysis``, per-collective HLO byte counts (plain runs) or the
per-rank records (``--pp`` runs: layers, per-chunk in-flight, memory,
analytic breakdown, plus top-level ``tp``/``zero``/``sp``).
Existing artifacts are reused unless ``--force``;
``benchmarks/validate_memory.py`` consumes them.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_spec
from repro.core.parallel_config import RecomputePolicy, ZeROStage
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, batch_shardings, batch_specs,
                                cache_shardings, input_specs,
                                shape_skip_reason, spec_for_shape)
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.optim.adamw import TrainState
from repro.parallel.axes import axis_rules
from repro.parallel.sharding import grad_shardings, state_shardings
from repro.serving.decode import make_serve_step
from repro.train.loop import TrainConfig, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

_OP_DEF_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue   # token like u32 index types unknown -> skipped above
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result sizes of every collective op-def in optimized HLO
    (handles variadic tuple-shaped collectives; skips -done halves so async
    pairs count once)."""
    per_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_DEF_RE.search(line)
        if not m:
            continue
        shape_text, kind, suffix = m.groups()
        if suffix == "-done":
            continue
        nbytes = _shape_bytes(shape_text)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


def _mem_dict(mem) -> Dict[str, float]:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def build_step(arch: str, shape_name: str, *, attn_impl: str = "naive",
               recompute: str = "none", zero: str = "os+g",
               n_micro: int = 1, capacity_factor: float = 1.25,
               scan_layers: bool = True, spec_override=None,
               moe_impl: str = "scatter", backend: str = "reference"):
    """Returns (fn, abstract_args, in_shardings, out_shardings, meta)."""
    spec0 = spec_override if spec_override is not None else get_spec(arch)
    spec = spec_for_shape(spec0, shape_name)
    info = SHAPES[shape_name]
    opts = ModelOptions(attn_impl=attn_impl,
                        recompute=RecomputePolicy(recompute),
                        capacity_factor=capacity_factor,
                        scan_layers=scan_layers,
                        moe_impl=moe_impl,
                        backend=backend)
    model = build_model(spec, opts)
    mesh = None  # bound by caller via axis_rules
    z = ZeROStage(zero)

    if info["kind"] == "train":
        from repro.optim.adamw import init_train_state
        step = make_train_step(model, TrainConfig(n_micro=n_micro))
        abstract_state = jax.eval_shape(init_train_state,
                                        model.abstract_params())
        batch = batch_specs(spec, info["batch"], info["seq"])
        return dict(kind="train", fn=step, model=model, spec=spec,
                    abstract_state=abstract_state, batch=batch, zero=z)
    if info["kind"] == "prefill":
        def prefill_fn(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        return dict(kind="prefill", fn=prefill_fn, model=model, spec=spec,
                    abstract_params=model.abstract_params(),
                    batch=batch_specs(spec, info["batch"], info["seq"]),
                    zero=z)
    # decode
    serve = make_serve_step(model)
    ins = input_specs(spec0, shape_name, model=model)
    return dict(kind="decode", fn=serve, model=model, spec=spec,
                abstract_params=model.abstract_params(),
                cache=ins["cache"], tokens=ins["tokens"], zero=z)


def lower_and_compile(built: Dict[str, Any], mesh) -> Dict[str, Any]:
    kind = built["kind"]
    z = built["zero"]
    t0 = time.perf_counter()
    with axis_rules(mesh):
        if kind == "train":
            st_sh = state_shardings(built["abstract_state"], mesh, z)
            b_sh = batch_shardings(built["batch"], mesh)
            lowered = jax.jit(
                built["fn"],
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
            ).lower(built["abstract_state"], built["batch"])
        elif kind == "prefill":
            p_sh = state_shardings(
                _fake_state(built["abstract_params"]), mesh, z).params
            b_sh = batch_shardings(built["batch"], mesh)
            lowered = jax.jit(
                built["fn"], in_shardings=(p_sh, b_sh),
            ).lower(built["abstract_params"], built["batch"])
        else:
            p_sh = state_shardings(
                _fake_state(built["abstract_params"]), mesh, z).params
            c_sh = cache_shardings(built["cache"], mesh)
            t_sh = batch_shardings({"t": built["tokens"]}, mesh)["t"]
            lowered = jax.jit(
                built["fn"], in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(None, c_sh),
            ).lower(built["abstract_params"], built["cache"], built["tokens"])
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    return dict(lowered=lowered, compiled=compiled,
                t_lower=t_lower, t_compile=t_compile)


def _fake_state(abstract_params):
    from repro.optim.adamw import TrainState
    z = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(step=z, params=abstract_params,
                      master=abstract_params, m=abstract_params,
                      v=abstract_params)


def _stage_input_shardings(mesh, arrs, sp: int = 1):
    """Shardings for the per-rank probe's in-flight boundary arrays
    (k, b, s[, h]): batch over the data axes; with ``sp`` > 1 additionally
    the seq dim of the bf16 boundary activations over 'model' — the
    executor's seq-sharded residency, so the probe's measured bytes carry
    the /sp divisor the analytic column models."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = []
    for a in arrs:
        entries = [None] * len(a.shape)
        if ba and a.shape[1] % int(np.prod([mesh.shape[x] for x in ba])) == 0:
            entries[1] = ba
        if sp > 1 and len(a.shape) >= 4 and "model" in mesh.axis_names \
                and a.shape[2] % mesh.shape["model"] == 0:
            entries[2] = "model"
        out.append(NamedSharding(mesh, P(*entries)))
    return tuple(out)


def _rank_params_slice(params, spec, chunks, firsts, lasts):
    """Heterogeneous per-rank parameter tree for a multi-chunk rank:
    {'shared': embed/final_norm/head owned by any of the rank's chunks,
    'chunks': one layers-only slice per chunk}.  Shared pieces are hoisted
    so a rank whose chunks own both ends (dualpipe rank 0) holds one copy —
    matching the stacked runtime layout and the analytic ``device_params``.
    """
    from repro.models.pipeline import chunk_params_slice
    shared = {}
    if any(firsts) or (spec.tie_embeddings and any(lasts)):
        shared["embed"] = params["embed"]
    if any(lasts):
        shared["final_norm"] = params["final_norm"]
        if not spec.tie_embeddings and "head" in params:
            shared["head"] = params["head"]
    # a list, not a tuple: adamw_update unpacks its per-leaf update triples
    # with is_leaf=isinstance(x, tuple)
    return {"shared": shared,
            "chunks": [chunk_params_slice(params, spec, ls, with_embed=False,
                                          with_head=False) for ls in chunks]}


def _make_rank_probe(spec, opts, chunks, firsts, lasts, in_flight):
    """Per-rank training-memory probe: for each of the rank's layer chunks,
    forward ``in_flight[c]`` microbatches with live activations (a scan
    whose backward consumes them last-in), then one accumulated backward +
    AdamW update — the schedule residency of the rank at its byte-weighted
    peak tick as one compilable program.  The last model chunk reduces via
    the real CE; all others via a mean-square surrogate (same backward
    structure)."""
    from repro.models.pipeline import make_chunk_fn
    from repro.optim.adamw import AdamWConfig, adamw_update
    fns = [make_chunk_fn(spec, opts, ls, is_first=f, is_last=l)
           for ls, f, l in zip(chunks, firsts, lasts)]
    total_k = max(sum(in_flight), 1)

    def probe(state, *arrs_flat):
        arrs_per_chunk, i = [], 0
        for c in range(len(chunks)):
            # first chunk: tokens only; interior: boundary x only;
            # last (and not first): boundary x + tokens for the CE
            n = 2 if (lasts[c] and not firsts[c]) else 1
            if in_flight[c] == 0:
                arrs_per_chunk.append(None)
                continue
            arrs_per_chunk.append(arrs_flat[i:i + n])
            i += n

        def scalar(params_):
            tot = jnp.zeros((), jnp.float32)
            for c, fn in enumerate(fns):
                if arrs_per_chunk[c] is None:
                    continue
                cp = dict(params_["chunks"][c])
                sh = params_["shared"]
                if firsts[c] or (spec.tie_embeddings and lasts[c]):
                    cp["embed"] = sh["embed"]
                if lasts[c]:
                    cp["final_norm"] = sh["final_norm"]
                    if "head" in sh:
                        cp["head"] = sh["head"]
                is_first, is_last = firsts[c], lasts[c]

                def body(acc, inp, fn=fn, is_first=is_first, is_last=is_last,
                         cp=cp):
                    if is_first:
                        x, tk = None, inp[0]
                    elif is_last:
                        x, tk = inp
                    else:
                        (x,), tk = inp, None
                    out, aux = fn(cp, x, tk)
                    if is_last:
                        targets = tk[:, 1:]
                        lg = out[:, :-1].astype(jnp.float32)
                        logz = jax.scipy.special.logsumexp(lg, axis=-1)
                        gold = jnp.take_along_axis(
                            lg, targets[..., None], axis=-1)[..., 0]
                        val = jnp.mean(logz - gold)
                    else:
                        val = jnp.mean(jnp.square(out.astype(jnp.float32)))
                    return acc + val + 0.01 * aux, None

                part, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                       arrs_per_chunk[c])
                tot = tot + part
            return tot / total_k
        grads = jax.tree.map(lambda g: g.astype(jnp.float32),
                             jax.grad(scalar)(state.params))
        new_state, _ = adamw_update(state, grads, AdamWConfig())
        return new_state

    return probe


def run_pp(arch: str, shape_name: str, pp: int, *, multi_pod: bool = False,
           force: bool = False, tag_suffix: str = "", mesh_shape=None,
           schedule: str = "1f1b", n_chunks: int = 1, sp: int = 1,
           ep: Optional[int] = None,
           **build_kw) -> Dict[str, Any]:
    """--pp N [--schedule ...]: lower + compile each pipeline rank as its
    own program on the rank's (data/pp, model) sub-mesh and record per-rank
    memory_analysis next to the analytical estimate_memory(stage=r,
    schedule=...).

    Each rank's probe holds the schedule's in-flight microbatch counts at
    the rank's byte-weighted peak tick — per chunk under interleaved /
    dualpipe — so the measured temp bytes carry the same schedule residency
    the analytic column models.  This is the heterogeneous view (true rank
    params: embedding with the first model chunk, head with the last, both
    ends on the boundary ranks under dualpipe) — no SPMD padding — so the
    records are directly comparable to the paper's per-stage Tables 4/5
    arithmetic."""
    from repro.core import estimate_memory, make_schedule
    from repro.core.activations import (layers_activation_bytes,
                                        rank_chunk_layers)
    from repro.core.parallel_config import ParallelConfig
    from repro.core.schedules import norm_chunks, n_model_chunks
    from repro.models.pipeline import check_pipeline_supported
    from repro.optim.adamw import init_train_state

    os.makedirs(ART_DIR, exist_ok=True)
    data, model_ax = tuple(mesh_shape) if mesh_shape else (16, 16)
    mesh_tag = ("pod2x" if multi_pod else "pod") + f"{data}x{model_ax}"
    v = norm_chunks(schedule, n_chunks)
    sched_tag = "" if schedule == "1f1b" else f"__{schedule}{v}"
    zero = build_kw.get("zero", "os+g")
    zero_tag = "" if zero == "os+g" else f"__z{zero.replace('+', '')}"
    if sp not in (1, model_ax):
        raise ValueError(f"--sp must be 1 or the TP degree {model_ax} "
                         f"(Megatron SP ties sp to tp), got {sp}")
    sp_tag = "" if sp == 1 else f"__sp{sp}"
    # --ep: explicit EP degree.  None keeps the legacy behaviour (analytic
    # ep = min(tp, n_routed), measured layout = the DEFAULT_RULES expert
    # shard) under the legacy untagged artifact name; an explicit value
    # pins BOTH sides — ep>1 shards the expert dim over 'model' (full axis,
    # like the executor's a2a layout), ep=1 pins the ETP layout (expert-ff
    # over 'model', experts replicated) — so an __ep1/__ep2 artifact pair
    # isolates exactly the dispatch-buffer /ep shrink.
    ep_tag = "" if ep is None else f"__ep{ep}"
    # --backend pallas: the kernel fast path.  The probe only COMPILES
    # (interpret-mode pallas lowers to pure jax ops off-TPU), but the
    # analytic column switches to flash accounting — cfg.attn_impl drops
    # the resident 5·b·n_h·s² buffers — so the tagged __pallas artifact
    # pairs with its untagged twin to isolate exactly that delta.
    backend = build_kw.get("backend", "reference")
    bk_tag = "" if backend == "reference" else "__pallas"
    tag = (f"{arch}__{shape_name}__{mesh_tag}__pp{pp}{sched_tag}{zero_tag}"
           f"{sp_tag}{ep_tag}{bk_tag}{tag_suffix}")
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    info = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "pp": pp,
                           "schedule": schedule, "n_chunks": v,
                           "tp": model_ax, "zero": zero, "sp": sp,
                           "backend": backend,
                           "mesh": mesh_tag, "options": build_kw}
    if ep is not None:
        rec["ep"] = ep
    try:
        if info["kind"] != "train":
            raise NotImplementedError("--pp covers training shapes only "
                                      "(the paper's per-stage analysis)")
        spec = spec_for_shape(get_spec(arch), shape_name)
        check_pipeline_supported(spec)
        if data % pp:
            raise ValueError(f"pp={pp} must divide data axis {data}")
        n_micro = max(build_kw.get("n_micro", 1), 1)
        opts = ModelOptions(
            attn_impl=build_kw.get("attn_impl", "naive"),
            recompute=RecomputePolicy(build_kw.get("recompute", "none")),
            capacity_factor=build_kw.get("capacity_factor", 1.25),
            moe_impl=build_kw.get("moe_impl", "scatter"),
            backend=backend)
        model = build_model(spec, opts)
        params_abs = model.abstract_params()
        mesh = make_production_mesh(multi_pod=multi_pod,
                                    shape=(data // pp, model_ax))
        dp = (data // pp) * (2 if multi_pod else 1)
        b_micro = max(info["batch"] // n_micro, 1)
        n_exp = spec.moe.n_routed if spec.is_moe else None
        if ep is None:                  # legacy: analytic ep follows the mesh
            ep_eff = min(model_ax, n_exp) if n_exp else 1
        else:
            if not spec.is_moe:
                raise ValueError(f"--ep needs an MoE arch, {arch} is dense")
            if ep not in (1, model_ax):
                raise ValueError(
                    f"--ep must be 1 or the TP degree {model_ax} (the "
                    f"expert-dim shard spans the whole 'model' axis, like "
                    f"the executor's a2a group), got {ep}")
            if n_exp % ep:
                raise ValueError(f"--ep {ep} does not divide "
                                 f"n_routed={n_exp}")
            ep_eff = ep
        rec["ep"] = ep_eff
        cfg = ParallelConfig(
            dp=dp, tp=model_ax, pp=pp, ep=ep_eff, etp=1, sp=sp > 1,
            zero=ZeROStage(build_kw.get("zero", "os+g")),
            recompute=RecomputePolicy(build_kw.get("recompute", "none")),
            micro_batch=max(b_micro // dp, 1), seq_len=info["seq"],
            attn_impl="flash" if backend == "pallas"
            else build_kw.get("attn_impl", "naive"))
        sched = make_schedule(schedule, pp, n_micro, n_chunks=v)
        all_chunks = rank_chunk_layers(spec, pp, schedule=schedule,
                                       n_chunks=v)
        g_total = n_model_chunks(schedule, pp, v)
        stages = []
        # --sp: route the logical "seq" axis onto 'model' so the probe's
        # boundary/residual constraints shard the sequence — the measured
        # counterpart of the analytic /sp divisor.  --ep: pin the expert
        # rules to the probed placement (ep>1: expert dim over 'model',
        # full ff — the executor's EP layout; ep=1: the ETP layout) so the
        # __ep pair's measured dispatch-buffer bytes track the analytic
        # (E/ep, C, h) term.
        probe_rules: Dict[str, Any] = {}
        if sp > 1:
            probe_rules["seq"] = "model"
        if ep is not None:
            probe_rules.update({"expert": "model", "expert_ff": None}
                               if ep > 1 else
                               {"expert": None, "expert_ff": "model"})
        with axis_rules(mesh, probe_rules or None):
            for r in range(pp):
                chunks = all_chunks[r]
                placed = sched.placement[r]
                firsts = [g == 0 for g in placed]
                lasts = [g == g_total - 1 for g in placed]
                weights = [layers_activation_bytes(spec, cfg, ls)
                           for ls in chunks]
                _, ks = sched.peak_profile(r, weights)
                abstract_rank = jax.eval_shape(
                    lambda p: _rank_params_slice(p, spec, chunks, firsts,
                                                 lasts), params_abs)
                abstract_state = jax.eval_shape(init_train_state,
                                                abstract_rank)
                arrs = []
                for c, k in enumerate(ks):
                    if k == 0:
                        continue
                    if firsts[c]:
                        arrs.append(jax.ShapeDtypeStruct(
                            (k, b_micro, info["seq"]), jnp.int32))
                    else:
                        arrs.append(jax.ShapeDtypeStruct(
                            (k, b_micro, info["seq"], spec.h), jnp.bfloat16))
                        if lasts[c]:
                            arrs.append(jax.ShapeDtypeStruct(
                                (k, b_micro, info["seq"]), jnp.int32))
                probe = _make_rank_probe(spec, opts, chunks, firsts, lasts,
                                         list(ks))
                st_sh = state_shardings(abstract_state, mesh, cfg.zero,
                                        rules=probe_rules or None)
                in_sh = _stage_input_shardings(mesh, arrs, sp=sp)
                t0 = time.perf_counter()
                compiled = jax.jit(
                    probe, in_shardings=(st_sh,) + in_sh,
                    out_shardings=st_sh,
                ).lower(abstract_state, *arrs).compile()
                t_c = time.perf_counter() - t0
                mem = compiled.memory_analysis()
                est = estimate_memory(spec, cfg, stage=r, schedule=schedule,
                                      n_chunks=v, n_micro=n_micro)
                stages.append({
                    "stage": r,
                    "layers": [int(l) for ls in chunks for l in ls],
                    "chunks": [{"model_chunk": int(placed[c]),
                                "layers": [int(l) for l in chunks[c]],
                                "in_flight": int(ks[c])}
                               for c in range(len(chunks))],
                    "in_flight": int(sum(ks)), "t_compile_s": t_c,
                    "memory": _mem_dict(mem),
                    "analytic": {kk: int(vv)
                                 for kk, vv in est.breakdown().items()},
                })
                print(f"[{tag}] rank {r}: in_flight={list(ks)} "
                      f"temp={stages[-1]['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} GiB "
                      f"analytic_act={est.activations/2**30:.2f} GiB")
        temps = [st["memory"].get("temp_size_in_bytes", 0) for st in stages]
        acts = [st["analytic"]["activations"] for st in stages]
        rec.update(status="ok", stages=stages,
                   measured_temp_stage0_over_last=(temps[0] / max(temps[-1], 1)),
                   analytic_act_stage0_over_last=(acts[0] / max(acts[-1], 1)))
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{tag}] {rec['status']}"
          + (f" ({rec.get('error', '')})" if rec["status"] == "error" else ""))
    return rec


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            force: bool = False, tag_suffix: str = "",
            mesh_shape=None, **build_kw) -> Dict[str, Any]:
    os.makedirs(ART_DIR, exist_ok=True)
    if mesh_shape is not None:
        mesh_tag = "pod" + ("2x" if multi_pod else "") \
            + "x".join(map(str, mesh_shape))
    else:
        mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    bk_tag = "" if build_kw.get("backend", "reference") == "reference" \
        else "__pallas"
    tag = f"{arch}__{shape_name}__{mesh_tag}{bk_tag}{tag_suffix}"
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    spec = get_spec(arch)
    skip = shape_skip_reason(spec, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "options": build_kw}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
    else:
        try:
            mesh = make_production_mesh(multi_pod=multi_pod,
                                        shape=mesh_shape)
            built = build_step(arch, shape_name, **build_kw)
            art = lower_and_compile(built, mesh)
            compiled = art["compiled"]
            mem = compiled.memory_analysis()
            print(mem)                       # proves it fits / reports bytes
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):   # older jax: list of dicts
                cost = cost[0] if cost else {}
            print({k: v for k, v in list(cost.items())[:8]})
            hlo = compiled.as_text()
            rec.update(
                status="ok",
                t_lower_s=art["t_lower"],
                t_compile_s=art["t_compile"],
                memory=_mem_dict(mem),
                flops=float(cost.get("flops", -1)),
                bytes_accessed=float(cost.get("bytes accessed", -1)),
                transcendentals=float(cost.get("transcendentals", -1)),
                collectives=collective_bytes(hlo),
                hlo_size_chars=len(hlo),
            )
        except Exception as e:
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    print(f"[{tag}] {status}" + (f" ({rec.get('error','')})"
                                 if status == "error" else ""))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero", default="os+g",
                    choices=[z.value for z in ZeROStage])
    ap.add_argument("--recompute", default="none",
                    choices=[r.value for r in RecomputePolicy])
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="kernel backend for the hot ops: 'pallas' routes "
                         "rmsnorm/attention/grouped-mlp through the Pallas "
                         "kernels (interpret mode off-TPU; compile-only in "
                         "this probe), upgrades causal attention to the "
                         "flash kernel and switches the analytic column to "
                         "flash accounting (drops the resident 5·b·n_h·s² "
                         "buffers); tags the artifact __pallas — run the "
                         "tagged/untagged pair to measure the delta")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages: >1 compiles each stage as its own "
                         "program and records per-stage memory_analysis")
    ap.add_argument("--tp", type=int, default=None,
                    help="override the mesh's 'model' axis (TP degree) — "
                         "with --pp/--zero this gives joint 3D+ZeRO probes "
                         "on small fake meshes, e.g. --pp 2 --tp 2 --zero os")
    ap.add_argument("--sp", type=int, default=1,
                    help="sequence-parallel degree for --pp probes (1 or "
                         "the TP degree — Megatron SP ties sp to tp): "
                         "shards the probe's boundary/residual seq dims "
                         "over 'model', tags the artifact __sp<N> and sets "
                         "the analytic sp divisor")
    ap.add_argument("--ep", type=int, default=None,
                    help="expert-parallel degree for --pp probes on MoE "
                         "archs (1 or the TP degree — the expert shard "
                         "spans the whole 'model' axis, like the "
                         "executor's a2a group): >1 shards expert weights "
                         "on their expert dim (full ff), 1 pins the ETP "
                         "layout; tags the artifact __ep<N> and sets the "
                         "analytic ep divisor — run the __ep1/__ep2 pair "
                         "to measure the (E/ep, C, h) dispatch-buffer "
                         "shrink")
    ap.add_argument("--schedule", default="1f1b",
                    choices=["1f1b", "interleaved", "dualpipe", "zb1p"],
                    help="pipeline schedule for --pp probes: sets per-rank "
                         "chunk layout and in-flight residency (zb1p: 1f1b "
                         "activation residency + the fp32 pending-dW stash "
                         "in the analytic grads column)")
    ap.add_argument("--bench-steps", type=int, default=None, metavar="ITERS",
                    help="run the measured step-time benchmark instead of "
                         "compile probes: benchmarks/step_bench.py grid "
                         "(schedule x pp on the 8-fake-device mesh), "
                         "ITERS timed windows per config, rows appended "
                         "newest-wins to benchmarks/artifacts/"
                         "BENCH_step.json; spawned as a subprocess so its "
                         "device count is independent of this dry-run's")
    ap.add_argument("--pp-chunks", type=int, default=None,
                    help="virtual stages per rank (interleaved: >=2; "
                         "defaults to 2 for interleaved/dualpipe)")
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "a2a"])
    ap.add_argument("--mesh-shape", default=None,
                    help="override per-pod grid, e.g. 32x8")
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()
    if args.bench_steps is not None:
        import subprocess
        bench = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "benchmarks", "step_bench.py")
        cmd = [sys.executable, os.path.abspath(bench),
               "--iters", str(args.bench_steps)]
        env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
        return subprocess.call(cmd, env=env)
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None
    if args.tp:
        base = mesh_shape if mesh_shape else (16, 16)
        mesh_shape = (base[0], args.tp)

    build_kw = dict(zero=args.zero, recompute=args.recompute,
                    attn_impl=args.attn, n_micro=args.n_micro,
                    capacity_factor=args.capacity_factor,
                    moe_impl=args.moe_impl, backend=args.backend)

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch & --shape or --all"
        combos = [(args.arch, args.shape)]

    if args.sp > 1 and args.pp <= 1:
        ap.error("--sp applies to the per-rank --pp probes; pass --pp N "
                 "(the plain-probe path would silently measure replicated "
                 "sequence dims under an __sp tagless artifact)")
    if args.ep is not None and args.pp <= 1:
        ap.error("--ep applies to the per-rank --pp probes; pass --pp N")
    failures = 0
    n_chunks = args.pp_chunks if args.pp_chunks is not None \
        else (1 if args.schedule in ("1f1b", "zb1p") else 2)
    for a, s in combos:
        if args.pp > 1:
            rec = run_pp(a, s, args.pp, multi_pod=args.multi_pod,
                         force=args.force, tag_suffix=args.tag_suffix,
                         mesh_shape=mesh_shape, schedule=args.schedule,
                         n_chunks=n_chunks, sp=args.sp, ep=args.ep,
                         **build_kw)
        else:
            rec = run_one(a, s, multi_pod=args.multi_pod, force=args.force,
                          tag_suffix=args.tag_suffix, mesh_shape=mesh_shape,
                          **build_kw)
        if rec["status"] == "error":
            failures += 1
    print(f"done: {len(combos)} combos, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
