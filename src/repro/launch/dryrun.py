import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) on placeholder devices; record memory_analysis / cost_analysis /
collective bytes for EXPERIMENTS.md §Dry-run and the §Roofline table.

The two lines above MUST precede any other import (jax locks the device
count at first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3 --shape train_4k \
      --zero os+g --recompute full --attn chunked --n-micro 16

Results cache to benchmarks/artifacts/dryrun/<tag>.json; --force recomputes.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_spec
from repro.core.parallel_config import RecomputePolicy, ZeROStage
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, batch_shardings, batch_specs,
                                cache_shardings, input_specs,
                                shape_skip_reason, spec_for_shape)
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.optim.adamw import TrainState
from repro.parallel.axes import axis_rules
from repro.parallel.sharding import grad_shardings, state_shardings
from repro.serving.decode import make_serve_step
from repro.train.loop import TrainConfig, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

_OP_DEF_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue   # token like u32 index types unknown -> skipped above
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result sizes of every collective op-def in optimized HLO
    (handles variadic tuple-shaped collectives; skips -done halves so async
    pairs count once)."""
    per_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_DEF_RE.search(line)
        if not m:
            continue
        shape_text, kind, suffix = m.groups()
        if suffix == "-done":
            continue
        nbytes = _shape_bytes(shape_text)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


def _mem_dict(mem) -> Dict[str, float]:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def build_step(arch: str, shape_name: str, *, attn_impl: str = "naive",
               recompute: str = "none", zero: str = "os+g",
               n_micro: int = 1, capacity_factor: float = 1.25,
               scan_layers: bool = True, spec_override=None,
               moe_impl: str = "scatter"):
    """Returns (fn, abstract_args, in_shardings, out_shardings, meta)."""
    spec0 = spec_override if spec_override is not None else get_spec(arch)
    spec = spec_for_shape(spec0, shape_name)
    info = SHAPES[shape_name]
    opts = ModelOptions(attn_impl=attn_impl,
                        recompute=RecomputePolicy(recompute),
                        capacity_factor=capacity_factor,
                        scan_layers=scan_layers,
                        moe_impl=moe_impl)
    model = build_model(spec, opts)
    mesh = None  # bound by caller via axis_rules
    z = ZeROStage(zero)

    if info["kind"] == "train":
        from repro.optim.adamw import init_train_state
        step = make_train_step(model, TrainConfig(n_micro=n_micro))
        abstract_state = jax.eval_shape(init_train_state,
                                        model.abstract_params())
        batch = batch_specs(spec, info["batch"], info["seq"])
        return dict(kind="train", fn=step, model=model, spec=spec,
                    abstract_state=abstract_state, batch=batch, zero=z)
    if info["kind"] == "prefill":
        def prefill_fn(params, batch):
            logits, _ = model.forward(params, batch)
            return logits
        return dict(kind="prefill", fn=prefill_fn, model=model, spec=spec,
                    abstract_params=model.abstract_params(),
                    batch=batch_specs(spec, info["batch"], info["seq"]),
                    zero=z)
    # decode
    serve = make_serve_step(model)
    ins = input_specs(spec0, shape_name, model=model)
    return dict(kind="decode", fn=serve, model=model, spec=spec,
                abstract_params=model.abstract_params(),
                cache=ins["cache"], tokens=ins["tokens"], zero=z)


def lower_and_compile(built: Dict[str, Any], mesh) -> Dict[str, Any]:
    kind = built["kind"]
    z = built["zero"]
    t0 = time.perf_counter()
    with axis_rules(mesh):
        if kind == "train":
            st_sh = state_shardings(built["abstract_state"], mesh, z)
            b_sh = batch_shardings(built["batch"], mesh)
            lowered = jax.jit(
                built["fn"],
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
            ).lower(built["abstract_state"], built["batch"])
        elif kind == "prefill":
            p_sh = state_shardings(
                _fake_state(built["abstract_params"]), mesh, z).params
            b_sh = batch_shardings(built["batch"], mesh)
            lowered = jax.jit(
                built["fn"], in_shardings=(p_sh, b_sh),
            ).lower(built["abstract_params"], built["batch"])
        else:
            p_sh = state_shardings(
                _fake_state(built["abstract_params"]), mesh, z).params
            c_sh = cache_shardings(built["cache"], mesh)
            t_sh = batch_shardings({"t": built["tokens"]}, mesh)["t"]
            lowered = jax.jit(
                built["fn"], in_shardings=(p_sh, c_sh, t_sh),
                out_shardings=(None, c_sh),
            ).lower(built["abstract_params"], built["cache"], built["tokens"])
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    return dict(lowered=lowered, compiled=compiled,
                t_lower=t_lower, t_compile=t_compile)


def _fake_state(abstract_params):
    from repro.optim.adamw import TrainState
    z = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(step=z, params=abstract_params,
                      master=abstract_params, m=abstract_params,
                      v=abstract_params)


def _stage_input_shardings(mesh, arrs):
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not ba:
        return tuple(NamedSharding(mesh, P()) for _ in arrs)
    out = []
    for a in arrs:
        if a.shape[1] % int(np.prod([mesh.shape[x] for x in ba])) == 0:
            out.append(NamedSharding(
                mesh, P(None, ba, *(None,) * (len(a.shape) - 2))))
        else:
            out.append(NamedSharding(mesh, P()))
    return tuple(out)


def _make_stage_probe(spec, opts, pp, stage, in_flight):
    """Per-stage training-memory probe: forward ``in_flight`` microbatches
    with live activations (a scan whose backward consumes them last-in) then
    one accumulated backward + AdamW update — the 1F1B residency of stage
    ``stage`` as one compilable program.  Last stage reduces via the real CE;
    interior stages via a mean-square surrogate (same backward structure)."""
    from repro.models.pipeline import make_stage_fn
    from repro.optim.adamw import AdamWConfig, adamw_update
    fwd = make_stage_fn(spec, opts, pp, stage)
    is_first, is_last = stage == 0, stage == pp - 1

    def probe(state, *arrs):
        def scalar(params_):
            def body(c, inp):
                if is_first:
                    x, tk = None, inp[0]
                elif is_last:
                    x, tk = inp
                else:
                    (x,), tk = inp, None
                out, aux = fwd(params_, x, tk)
                if is_last:
                    targets = tk[:, 1:]
                    lg = out[:, :-1].astype(jnp.float32)
                    logz = jax.scipy.special.logsumexp(lg, axis=-1)
                    gold = jnp.take_along_axis(
                        lg, targets[..., None], axis=-1)[..., 0]
                    val = jnp.mean(logz - gold)
                else:
                    val = jnp.mean(jnp.square(out.astype(jnp.float32)))
                return c + val + 0.01 * aux, None
            tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), arrs)
            return tot / in_flight
        grads = jax.tree.map(lambda g: g.astype(jnp.float32),
                             jax.grad(scalar)(state.params))
        new_state, _ = adamw_update(state, grads, AdamWConfig())
        return new_state

    return probe


def run_pp(arch: str, shape_name: str, pp: int, *, multi_pod: bool = False,
           force: bool = False, tag_suffix: str = "", mesh_shape=None,
           **build_kw) -> Dict[str, Any]:
    """--pp N: lower + compile each pipeline stage as its own program on the
    stage's (data/pp, model) sub-mesh and record per-stage memory_analysis
    next to the analytical estimate_memory(stage=s, in_flight=1F1B(s)).

    This is the heterogeneous view (true stage params: embed on stage 0,
    head on the last) — no SPMD padding — so the records are directly
    comparable to the paper's per-stage Tables 4/5 arithmetic."""
    from repro.core import estimate_memory, one_f1b_in_flight
    from repro.core.parallel_config import ParallelConfig
    from repro.models.pipeline import (check_pipeline_supported, partition,
                                       stage_params_slice)
    from repro.optim.adamw import init_train_state

    os.makedirs(ART_DIR, exist_ok=True)
    data, model_ax = tuple(mesh_shape) if mesh_shape else (16, 16)
    mesh_tag = ("pod2x" if multi_pod else "pod") + f"{data}x{model_ax}"
    tag = f"{arch}__{shape_name}__{mesh_tag}__pp{pp}{tag_suffix}"
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    info = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "pp": pp,
                           "mesh": mesh_tag, "options": build_kw}
    try:
        if info["kind"] != "train":
            raise NotImplementedError("--pp covers training shapes only "
                                      "(the paper's per-stage analysis)")
        spec = spec_for_shape(get_spec(arch), shape_name)
        check_pipeline_supported(spec)
        if data % pp:
            raise ValueError(f"pp={pp} must divide data axis {data}")
        n_micro = max(build_kw.get("n_micro", 1), 1)
        opts = ModelOptions(
            attn_impl=build_kw.get("attn_impl", "naive"),
            recompute=RecomputePolicy(build_kw.get("recompute", "none")),
            capacity_factor=build_kw.get("capacity_factor", 1.25),
            moe_impl=build_kw.get("moe_impl", "scatter"))
        model = build_model(spec, opts)
        params_abs = model.abstract_params()
        mesh = make_production_mesh(multi_pod=multi_pod,
                                    shape=(data // pp, model_ax))
        dp = (data // pp) * (2 if multi_pod else 1)
        b_micro = max(info["batch"] // n_micro, 1)
        n_exp = spec.moe.n_routed if spec.is_moe else None
        ep = min(model_ax, n_exp) if n_exp else 1
        cfg = ParallelConfig(
            dp=dp, tp=model_ax, pp=pp, ep=ep, etp=1, sp=True,
            zero=ZeROStage(build_kw.get("zero", "os+g")),
            recompute=RecomputePolicy(build_kw.get("recompute", "none")),
            micro_batch=max(b_micro // dp, 1), seq_len=info["seq"])
        stages = []
        with axis_rules(mesh):
            for s in range(pp):
                k = one_f1b_in_flight(pp, s, n_micro)
                abstract_stage = jax.eval_shape(
                    lambda p: stage_params_slice(p, spec, pp, s), params_abs)
                abstract_state = jax.eval_shape(init_train_state,
                                                abstract_stage)
                arrs = []
                if s == 0:
                    arrs.append(jax.ShapeDtypeStruct(
                        (k, b_micro, info["seq"]), jnp.int32))
                else:
                    arrs.append(jax.ShapeDtypeStruct(
                        (k, b_micro, info["seq"], spec.h), jnp.bfloat16))
                    if s == pp - 1:
                        arrs.append(jax.ShapeDtypeStruct(
                            (k, b_micro, info["seq"]), jnp.int32))
                probe = _make_stage_probe(spec, opts, pp, s, k)
                st_sh = state_shardings(abstract_state, mesh, cfg.zero)
                in_sh = _stage_input_shardings(mesh, arrs)
                t0 = time.perf_counter()
                compiled = jax.jit(
                    probe, in_shardings=(st_sh,) + in_sh,
                    out_shardings=st_sh,
                ).lower(abstract_state, *arrs).compile()
                t_c = time.perf_counter() - t0
                mem = compiled.memory_analysis()
                est = estimate_memory(spec, cfg, stage=s,
                                      in_flight_microbatches=k)
                stages.append({
                    "stage": s, "layers": [int(l) for l in
                                           partition(spec, pp).stages[s]],
                    "in_flight": k, "t_compile_s": t_c,
                    "memory": _mem_dict(mem),
                    "analytic": {kk: int(vv)
                                 for kk, vv in est.breakdown().items()},
                })
                print(f"[{tag}] stage {s}: in_flight={k} "
                      f"temp={stages[-1]['memory'].get('temp_size_in_bytes', 0)/2**30:.2f} GiB "
                      f"analytic_act={est.activations/2**30:.2f} GiB")
        temps = [st["memory"].get("temp_size_in_bytes", 0) for st in stages]
        acts = [st["analytic"]["activations"] for st in stages]
        rec.update(status="ok", stages=stages,
                   measured_temp_stage0_over_last=(temps[0] / max(temps[-1], 1)),
                   analytic_act_stage0_over_last=(acts[0] / max(acts[-1], 1)))
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{tag}] {rec['status']}"
          + (f" ({rec.get('error', '')})" if rec["status"] == "error" else ""))
    return rec


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            force: bool = False, tag_suffix: str = "",
            mesh_shape=None, **build_kw) -> Dict[str, Any]:
    os.makedirs(ART_DIR, exist_ok=True)
    if mesh_shape is not None:
        mesh_tag = "pod" + ("2x" if multi_pod else "") \
            + "x".join(map(str, mesh_shape))
    else:
        mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}{tag_suffix}"
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    spec = get_spec(arch)
    skip = shape_skip_reason(spec, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "options": build_kw}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
    else:
        try:
            mesh = make_production_mesh(multi_pod=multi_pod,
                                        shape=mesh_shape)
            built = build_step(arch, shape_name, **build_kw)
            art = lower_and_compile(built, mesh)
            compiled = art["compiled"]
            mem = compiled.memory_analysis()
            print(mem)                       # proves it fits / reports bytes
            cost = compiled.cost_analysis()
            print({k: v for k, v in list(cost.items())[:8]})
            hlo = compiled.as_text()
            rec.update(
                status="ok",
                t_lower_s=art["t_lower"],
                t_compile_s=art["t_compile"],
                memory=_mem_dict(mem),
                flops=float(cost.get("flops", -1)),
                bytes_accessed=float(cost.get("bytes accessed", -1)),
                transcendentals=float(cost.get("transcendentals", -1)),
                collectives=collective_bytes(hlo),
                hlo_size_chars=len(hlo),
            )
        except Exception as e:
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    print(f"[{tag}] {status}" + (f" ({rec.get('error','')})"
                                 if status == "error" else ""))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero", default="os+g",
                    choices=[z.value for z in ZeROStage])
    ap.add_argument("--recompute", default="none",
                    choices=[r.value for r in RecomputePolicy])
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages: >1 compiles each stage as its own "
                         "program and records per-stage memory_analysis")
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "a2a"])
    ap.add_argument("--mesh-shape", default=None,
                    help="override per-pod grid, e.g. 32x8")
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None

    build_kw = dict(zero=args.zero, recompute=args.recompute,
                    attn_impl=args.attn, n_micro=args.n_micro,
                    capacity_factor=args.capacity_factor,
                    moe_impl=args.moe_impl)

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch & --shape or --all"
        combos = [(args.arch, args.shape)]

    failures = 0
    for a, s in combos:
        if args.pp > 1:
            rec = run_pp(a, s, args.pp, multi_pod=args.multi_pod,
                         force=args.force, tag_suffix=args.tag_suffix,
                         mesh_shape=mesh_shape, **build_kw)
        else:
            rec = run_one(a, s, multi_pod=args.multi_pod, force=args.force,
                          tag_suffix=args.tag_suffix, mesh_shape=mesh_shape,
                          **build_kw)
        if rec["status"] == "error":
            failures += 1
    print(f"done: {len(combos)} combos, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
