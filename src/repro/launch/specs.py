"""Input/State ShapeDtypeStruct stand-ins + shardings for the dry-run.

``input_specs(spec, shape_name)`` returns abstract inputs for the step kind
the shape dictates (train / prefill / decode), with no device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.notation import AttentionKind, FamilyKind, ModelSpec

PyTree = Any

# the assigned input-shape pool
SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

SLIDING_WINDOW_LONG = 8192     # dense archs' long_500k variant (DESIGN.md §4)


def shape_skip_reason(spec: ModelSpec, shape_name: str) -> Optional[str]:
    info = SHAPES[shape_name]
    if info["kind"] == "decode" and spec.family == FamilyKind.AUDIO \
            and shape_name == "long_500k":
        return ("whisper decoder max context is 448; long_500k decode is "
                "out of family scope (DESIGN.md §4)")
    return None


def spec_for_shape(spec: ModelSpec, shape_name: str) -> ModelSpec:
    """Architecture variant used for a given input shape: dense/MoE/VLM archs
    switch to the sliding-window decode variant for long_500k (sub-quadratic
    requirement); SSM/hybrid run natively."""
    if shape_name == "long_500k" and spec.attention != AttentionKind.NONE \
            and spec.ssm is None:
        return dataclasses.replace(spec, sliding_window=SLIDING_WINDOW_LONG)
    return spec


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(spec: ModelSpec, batch: int, seq: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {"tokens": _sds((batch, seq), jnp.int32)}
    if spec.family == FamilyKind.VLM:
        b["vision_embeds"] = _sds((batch, min(256, seq // 4), spec.h),
                                  jnp.bfloat16)
    if spec.encoder is not None:
        b["audio_embeds"] = _sds((batch, spec.encoder.n_ctx, spec.h),
                                 jnp.bfloat16)
    return b


def cache_specs(model, spec: ModelSpec, batch: int, cache_len: int
                ) -> PyTree:
    """Abstract cache pytree via eval_shape of init_cache."""
    enc = None
    if spec.encoder is not None:
        enc = _sds((batch, spec.encoder.n_ctx, spec.h), jnp.bfloat16)

    def mk(enc_out):
        return model.init_cache(batch, cache_len, enc_out=enc_out)

    if enc is not None:
        return jax.eval_shape(mk, enc)
    return jax.eval_shape(lambda: mk(None))


def input_specs(spec: ModelSpec, shape_name: str, model=None
                ) -> Dict[str, Any]:
    """Abstract inputs for (arch, shape): train/prefill → batch dict;
    decode → {'cache': ..., 'tokens': (b,1)}."""
    info = SHAPES[shape_name]
    sp = spec_for_shape(spec, shape_name)
    if info["kind"] in ("train", "prefill"):
        return {"batch": batch_specs(sp, info["batch"], info["seq"])}
    from repro.models import build_model
    model = model or build_model(sp)
    eff = min(info["seq"], sp.sliding_window) if sp.sliding_window \
        else info["seq"]
    cache = cache_specs(model, sp, info["batch"], eff)
    return {"cache": cache,
            "tokens": _sds((info["batch"], 1), jnp.int32)}


# ---------------------------------------------------------------------------
# shardings for inputs & caches
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shardings(abstract_batch: PyTree, mesh: Mesh) -> PyTree:
    ba = _batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))

    def one(leaf):
        if leaf.shape and leaf.shape[0] % bsz == 0:
            return NamedSharding(mesh, P(ba, *(None,) * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, abstract_batch)


def cache_placement(shape: Tuple[int, ...], batch_size: int, model_size: int
                    ) -> Tuple[Optional[str], ...]:
    """Single source of truth for cache-leaf placement (used by the dry-run
    shardings AND the analytical validation): 'batch' on dim 1 when
    divisible, else context-parallel 'batch' on dim 2 (long_500k b=1);
    'model' on the preferred heads/feature dim by rank."""
    if not shape:
        return ()
    dims: list = [None] * len(shape)
    if len(shape) >= 2 and shape[1] % batch_size == 0 and batch_size > 1:
        dims[1] = "batch"
    elif len(shape) >= 3 and shape[2] % batch_size == 0 and batch_size > 1:
        dims[2] = "batch"          # context-parallel: shard cache sequence
    # model-axis preference by rank:
    #   rank5 kv (L,b,s,n_kv,d) / ssm (L,b,nh,hd,sd): heads first, then the
    #   SEQUENCE dim, then head_dim.  Head_dim sharding is last on purpose:
    #   it makes the decode q·k contraction emit PARTIAL scores that
    #   all-reduce at full cache width (measured 3.9 s/chip of ICI on
    #   qwen2-vl decode_32k, §Perf hillclimb 3); seq-sharding keeps scores
    #   local and only reduces the tiny softmax stats / context partials.
    #   rank4 latent (L,b,s,d_c): feature dim;  rank3 (L,b,h): feature dim
    if model_size > 1:
        prefer = {5: (3, 2, 4), 4: (3,), 3: (2,)}.get(len(shape), ())
        for d in prefer:
            if dims[d] is None and shape[d] % model_size == 0 \
                    and shape[d] >= model_size:
                dims[d] = "model"
                break
    return tuple(dims)


def cache_divisor(shape: Tuple[int, ...], batch_size: int,
                  model_size: int) -> int:
    div = 1
    for d in cache_placement(shape, batch_size, model_size):
        if d == "batch":
            div *= batch_size
        elif d == "model":
            div *= model_size
    return div


def cache_shardings(abstract_cache: PyTree, mesh: Mesh) -> PyTree:
    ba = _batch_axes(mesh)
    bsz = int(np.prod([mesh.shape[a] for a in ba]))
    msz = mesh.shape.get("model", 1)

    def one(leaf):
        dims = [ba if d == "batch" else d
                for d in cache_placement(leaf.shape, bsz, msz)]
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, abstract_cache)
