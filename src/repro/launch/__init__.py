# Launch layer: production meshes, dry-run lowering, train/serve drivers.
