"""Fused RMSNorm Pallas TPU kernel.

The paper's block (Figure 1) runs two RMSNorms per layer over (tokens, h)
activations; fused normalisation avoids one HBM round-trip of the (T, h)
tensor (memory-bound op: arithmetic intensity ~O(1)).

Tiling: grid over row blocks; each program normalises a (block_rows, h)
tile held in VMEM.  h is padded by the caller to a multiple of 128 (lane
width); block_rows chosen so the tile fits VMEM (~16 MiB/core).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float,
                    gemma_style: bool):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    g = scale_ref[...].astype(jnp.float32)
    if gemma_style:
        g = 1.0 + g
    o_ref[...] = (y * g[None, :]).astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                   gemma_style: bool = False, block_rows: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (..., h) -> (..., h).  h should be a multiple of 128 on real TPU."""
    orig_shape = x.shape
    h = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, h)
    br = min(block_rows, rows)
    # pad rows to a block multiple
    n_blocks = -(-rows // br)
    pad = n_blocks * br - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, gemma_style=gemma_style),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * br, h), x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
