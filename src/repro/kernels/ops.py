"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated in interpret mode per the task brief).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mla_attention import flash_attention_pallas
from .moe_gmm import gmm_pallas, pad_groups
from .rmsnorm import rmsnorm_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "gemma_style",
                                             "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, gemma_style: bool = False,
            block_rows: int = 256, interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return rmsnorm_pallas(x, scale, eps=eps, gemma_style=gemma_style,
                          block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, scale=scale, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def gmm(lhs, rhs, expert_map, *, block_m: int = 128, block_n: int = 128,
        interpret: bool = None):
    interpret = _default_interpret() if interpret is None else interpret
    return gmm_pallas(lhs, rhs, expert_map, block_m=block_m, block_n=block_n,
                      interpret=interpret)


__all__ = ["rmsnorm", "flash_attention", "gmm", "pad_groups"]
