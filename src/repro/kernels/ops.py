"""jit'd public wrappers for the Pallas kernels.

TPU-vs-interpret contract
-------------------------
The kernels TARGET TPU; everywhere else they run in Pallas interpret
mode (pure-jax emulation — numerically identical, no Mosaic lowering).
The default is decided ONCE, at import time, from
``jax.default_backend()`` and cached in ``_INTERPRET``:

* it must not be re-read inside a jitted body — ``interpret`` is a
  static argument of ``pallas_call``, so a per-call probe would bake a
  fresh Python bool into every trace and re-evaluate the backend query
  under jit for each call-site permutation;
* callers that jit *around* these wrappers (the model stack, the 3D
  executor) therefore see one stable configuration per process, which
  is the granularity at which the backend can actually change.

Pass ``interpret=`` explicitly to override per call (e.g. forcing
interpret mode on TPU for a numerics cross-check).  The public wrappers
are thin Python shims that resolve the default *before* dispatching to
the jitted inner functions, so ``interpret`` reaches jit already
concrete.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mla_attention import flash_attention_pallas
from .moe_gmm import gmm_pallas, pad_groups
from .rmsnorm import rmsnorm_pallas

# Resolved once at import: interpret everywhere except real TPU.
_INTERPRET: bool = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "gemma_style",
                                             "block_rows", "interpret"))
def _rmsnorm_jit(x, scale, *, eps, gemma_style, block_rows, interpret):
    return rmsnorm_pallas(x, scale, eps=eps, gemma_style=gemma_style,
                          block_rows=block_rows, interpret=interpret)


def rmsnorm(x, scale, *, eps: float = 1e-6, gemma_style: bool = False,
            block_rows: int = 256, interpret: bool = None):
    interpret = _INTERPRET if interpret is None else interpret
    return _rmsnorm_jit(x, scale, eps=eps, gemma_style=gemma_style,
                        block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret"))
def _flash_attention_jit(q, k, v, *, scale, causal, block_q, block_k,
                         interpret):
    return flash_attention_pallas(q, k, v, scale=scale, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    interpret = _INTERPRET if interpret is None else interpret
    return _flash_attention_jit(q, k, v, scale=scale, causal=causal,
                                block_q=block_q, block_k=block_k,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def _gmm_jit(lhs, rhs, expert_map, *, block_m, block_n, interpret):
    return gmm_pallas(lhs, rhs, expert_map, block_m=block_m, block_n=block_n,
                      interpret=interpret)


def gmm(lhs, rhs, expert_map, *, block_m: int = 128, block_n: int = 128,
        interpret: bool = None):
    interpret = _INTERPRET if interpret is None else interpret
    return _gmm_jit(lhs, rhs, expert_map, block_m=block_m, block_n=block_n,
                    interpret=interpret)


__all__ = ["rmsnorm", "flash_attention", "gmm", "pad_groups"]
