"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                gemma_style: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    g = scale.astype(jnp.float32)
    if gemma_style:
        g = 1.0 + g
    return (y * g).astype(x.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        scale: float, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention. q/k: (b,s,nh,dq), v: (b,s,nh,dv)."""
    s = q.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gmm_ref(lhs: jnp.ndarray, rhs: jnp.ndarray, expert_map: jnp.ndarray,
            *, block_m: int = 128) -> jnp.ndarray:
    """Row-block-wise grouped matmul oracle."""
    M, K = lhs.shape
    out = []
    for blk in range(M // block_m):
        e = int(expert_map[blk])
        xb = lhs[blk * block_m:(blk + 1) * block_m].astype(jnp.float32)
        out.append((xb @ rhs[e].astype(jnp.float32)).astype(lhs.dtype))
    return jnp.concatenate(out, axis=0)
