"""Flash-style causal attention Pallas TPU kernel (MLA-shaped).

The paper's dominant activation term is the 5·b·n_h·s² score/softmax family
(§5.1) — the tensor this kernel eliminates.  Online-softmax tiles keep the
working set at (block_q × block_k) in VMEM, so activation memory drops from
O(s²) to O(s), which is the memory-roofline win recorded in EXPERIMENTS.md
§Perf.

MLA shape notes: q/k head dim = d_h + d_hr (192 for DeepSeek-v3), v head
dim = d_v (128) — the kernel supports dq != dv.  GQA reuses the same kernel
after head replication.  MXU alignment: block_q/block_k multiples of 128;
dq=192 is 1.5 lanes — the compiler packs 192 = 128+64; on real TPU pad to
256 for peak MXU utilisation (benchmarks sweep both).

Grid: (batch*heads, q_blocks); the kernel fori-loops over k blocks up to the
causal frontier carrying (m, l, acc) in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  scale: float, seq_len: int, causal: bool):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (block_q, dq)
    dv = v_ref.shape[-1]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    n_kb = seq_len // block_k
    hi = jax.lax.min(((qi + 1) * block_q + block_k - 1) // block_k, n_kb) \
        if causal else n_kb

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                     # (block_q, block_k)
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, dv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           scale: float, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q/k: (b, s, n_h, dq); v: (b, s, n_h, dv) -> (b, s, n_h, dv).

    s is padded to a block multiple internally; causal masking makes the
    padding inert for the valid rows.
    """
    b, s, nh, dq = q.shape
    dv = v.shape[-1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    n_qb = -(-s // bq)
    s_pad = n_qb * bq
    # unify q/k padding to one padded length divisible by both blocks
    s_pad = -(-s_pad // bk) * bk
    n_qb = s_pad // bq
    if s_pad != s:
        padder = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        q = jnp.pad(q, padder)
        k = jnp.pad(k, padder)
        v = jnp.pad(v, padder)

    # fold batch & heads: (b*nh, s_pad, d)
    qf = q.transpose(0, 2, 1, 3).reshape(b * nh, s_pad, dq)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nh, s_pad, dq)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nh, s_pad, dv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq, block_k=bk, scale=scale,
                          seq_len=s_pad, causal=causal),
        grid=(b * nh, n_qb),
        in_specs=[
            pl.BlockSpec((None, bq, dq), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, s_pad, dq), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, s_pad, dv), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dv), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nh, s_pad, dv), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, nh, s_pad, dv).transpose(0, 2, 1, 3)
    return out[:, :s]
