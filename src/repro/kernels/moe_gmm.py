"""Grouped expert matmul (GMM) Pallas TPU kernel.

The MoE hot loop (paper §3.3/§5.2): after dispatch, each expert multiplies
its token slab by its own weights.  A loop of per-expert matmuls wastes MXU
time on small ragged groups; the megablox-style GMM walks one (M, K)×(E, K,
N) problem where rows are grouped by expert, with the row-block → expert map
prefetched to SMEM so each grid step loads the right expert's weight tile.

Caller contract: rows pre-sorted by expert, each group padded to a multiple
of block_m (``pad_groups`` below does both).  Tiles are MXU-aligned
(block_m × block_n = 128×128 default, K kept whole in VMEM — h_E=2048 and
h=7168 tiles fit comfortably: 128·7168·2B ≈ 1.8 MiB).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _gmm_kernel(expert_map_ref, lhs_ref, rhs_ref, out_ref):
    # expert_map is scalar-prefetched; BlockSpec index_maps already selected
    # the right expert tile of rhs, so the body is a plain MXU matmul.
    out_ref[...] = jnp.dot(
        lhs_ref[...].astype(jnp.float32), rhs_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def gmm_pallas(lhs: jnp.ndarray, rhs: jnp.ndarray, expert_map: jnp.ndarray,
               *, block_m: int = 128, block_n: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """lhs: (M, K) rows grouped by expert; rhs: (E, K, N);
    expert_map: (M//block_m,) int32 — expert id of each row block.
    Returns (M, N)."""
    M, K = lhs.shape
    E, K2, N = rhs.shape
    assert K == K2, (K, K2)
    assert M % block_m == 0, "pad groups to block_m first"
    bn = min(block_n, N)
    assert N % bn == 0
    grid = (M // block_m, N // bn)

    try:
        from jax.experimental.pallas import tpu as pltpu
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, K), lambda i, j, emap: (i, 0)),
                pl.BlockSpec((None, K, bn), lambda i, j, emap: (emap[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((block_m, bn), lambda i, j, emap: (i, j)),
        )
        return pl.pallas_call(
            _gmm_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
            interpret=interpret,
        )(expert_map, lhs, rhs)
    except (ImportError, NotImplementedError):
        # portable fallback grid spec (no scalar prefetch): pass the map as
        # a regular SMEM operand
        raise


def pad_groups(x: jnp.ndarray, group_sizes: np.ndarray, block_m: int
               ) -> Tuple[jnp.ndarray, np.ndarray, np.ndarray]:
    """Host-side helper (static group sizes): pad each expert's row group to
    a block_m multiple.  Returns (padded rows, expert_map, row_index) where
    row_index scatters padded rows back to originals (-1 = padding)."""
    E = len(group_sizes)
    padded_sizes = [(-(-int(g) // block_m)) * block_m for g in group_sizes]
    total = sum(padded_sizes)
    out = np.zeros((total,) + x.shape[1:], dtype=x.dtype)
    emap = []
    ridx = np.full((total,), -1, np.int64)
    src = 0
    dst = 0
    xnp = np.asarray(x)
    for e in range(E):
        g = int(group_sizes[e])
        out[dst:dst + g] = xnp[src:src + g]
        ridx[dst:dst + g] = np.arange(src, src + g)
        emap.extend([e] * (padded_sizes[e] // block_m))
        src += g
        dst += padded_sizes[e]
    return (jnp.asarray(out), np.asarray(emap, np.int32), ridx)
