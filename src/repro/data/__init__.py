from .synthetic import SyntheticConfig, batches, make_batch

__all__ = ["SyntheticConfig", "batches", "make_batch"]
