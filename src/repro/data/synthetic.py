"""Deterministic synthetic token pipeline.

Generates language-like token streams (Zipfian unigram + short-range
repetition structure so the loss actually decreases) plus the stub-frontend
embeddings for VLM/audio architectures.  Fully deterministic in (seed, step)
— reproducible across hosts, shardable along the batch dim.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.notation import FamilyKind, ModelSpec


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    zipf_alpha: float = 1.1
    repeat_prob: float = 0.3      # p(copy token from 8 back) — learnable signal
    n_vision_tokens: int = 0      # VLM stub patches
    n_audio_frames: int = 0       # audio stub frames
    h: int = 0


def _zipf_logits(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    return np.log(p / p.sum())


def make_batch(cfg: SyntheticConfig, step: int) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    logits = _zipf_logits(cfg.vocab, cfg.zipf_alpha)
    probs = np.exp(logits)
    toks = rng.choice(cfg.vocab, size=(cfg.batch, cfg.seq_len), p=probs)
    # inject copy structure: with prob repeat_prob, token = token[t-8]
    mask = rng.random((cfg.batch, cfg.seq_len)) < cfg.repeat_prob
    mask[:, :8] = False
    shifted = np.roll(toks, 8, axis=1)
    toks = np.where(mask, shifted, toks).astype(np.int32)
    batch: Dict[str, jnp.ndarray] = {"tokens": jnp.asarray(toks)}
    if cfg.n_vision_tokens:
        ve = rng.standard_normal(
            (cfg.batch, cfg.n_vision_tokens, cfg.h)).astype(np.float32)
        batch["vision_embeds"] = jnp.asarray(ve * 0.02, jnp.bfloat16)
    if cfg.n_audio_frames:
        ae = rng.standard_normal(
            (cfg.batch, cfg.n_audio_frames, cfg.h)).astype(np.float32)
        batch["audio_embeds"] = jnp.asarray(ae * 0.02, jnp.bfloat16)
    return batch


def batches(cfg: SyntheticConfig, n_steps: Optional[int] = None
            ) -> Iterator[Dict[str, jnp.ndarray]]:
    step = 0
    while n_steps is None or step < n_steps:
        yield make_batch(cfg, step)
        step += 1


def config_for(spec: ModelSpec, batch: int, seq_len: int,
               seed: int = 0) -> SyntheticConfig:
    nv = na = 0
    if spec.family == FamilyKind.VLM:
        nv = min(256, seq_len // 4)
    if spec.encoder is not None:
        na = spec.encoder.n_ctx
    return SyntheticConfig(batch=batch, seq_len=seq_len, vocab=spec.vocab,
                           seed=seed, n_vision_tokens=nv, n_audio_frames=na,
                           h=spec.h)
