"""Architecture config registry.

Each ``<arch>.py`` module defines ``SPEC`` (the full published configuration,
source cited in the module docstring) and ``SMOKE`` (a reduced variant of the
same family: <=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.

``get_spec(name, smoke=False)`` is the single lookup the launcher, dry-run,
benchmarks and tests all use (``--arch <id>``).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.notation import ModelSpec

ARCHS: List[str] = [
    "deepseek_v3",        # the paper's reference model
    "deepseek_v2",        # paper also covers v2
    "olmoe_1b_7b",
    "qwen2_vl_72b",
    "minitron_4b",
    "hymba_1_5b",
    "whisper_tiny",
    "rwkv6_1_6b",
    "gemma_2b",
    "qwen3_moe_235b_a22b",
    "gemma_7b",
    "qwen2_1_5b",
    # beyond the assigned pool: the small "qwen2-moe"-shaped probe arch the
    # EP dispatch-buffer validation pair runs on (dryrun --pp --tp --ep)
    "qwen2_moe_a2_7b",
]

# assigned pool ids (dashes) -> module names (underscores)
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "minitron-4b": "minitron_4b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "gemma-2b": "gemma_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "gemma-7b": "gemma_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-v3": "deepseek_v3",
    "deepseek-v2": "deepseek_v2",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
})

ASSIGNED: List[str] = [
    "olmoe-1b-7b", "qwen2-vl-72b", "minitron-4b", "hymba-1.5b",
    "whisper-tiny", "rwkv6-1.6b", "gemma-2b", "qwen3-moe-235b-a22b",
    "gemma-7b", "qwen2-1.5b",
]


def canonical(name: str) -> str:
    key = name.strip().lower()
    if key in _ALIASES:
        return _ALIASES[key]
    key = key.replace("-", "_").replace(".", "_")
    if key in ARCHS:
        return key
    raise KeyError(f"unknown architecture {name!r}; known: {sorted(_ALIASES)}")


def get_spec(name: str, smoke: bool = False) -> ModelSpec:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.SPEC


def all_specs(smoke: bool = False) -> Dict[str, ModelSpec]:
    return {a: get_spec(a, smoke=smoke) for a in ARCHS}
