"""OLMoE-1B-7B [arXiv:2409.02060] — 16L d_model=2048 16H (GQA kv=16)
expert d_ff=1024, vocab 50304; MoE 64 experts top-8, no shared experts,
every layer MoE (no dense FFN layers)."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind, MoESpec,
                                 ModelSpec)

SPEC = ModelSpec(
    name="olmoe-1b-7b",
    family=FamilyKind.MOE,
    n_layers=16,
    h=2048,
    n_h=16,
    n_kv=16,
    d_head=128,
    h_ff=0,                      # all layers are MoE
    vocab=50304,
    attention=AttentionKind.MHA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=64, n_active=8, n_shared=0, d_ff_expert=1024,
                first_k_dense=0),
    max_seq_len=4096,
)

SMOKE = ModelSpec(
    name="olmoe-smoke",
    family=FamilyKind.MOE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=64,
    h_ff=0,
    vocab=512,
    attention=AttentionKind.MHA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=4, n_active=2, n_shared=0, d_ff_expert=128,
                first_k_dense=0),
    max_seq_len=512,
)
