"""DeepSeek-V2 [arXiv:2405.04434] — 236B total / 21B active.

60 layers, h=5120, MLA (d_c=512, d_cq=1536), 160 routed experts top-6 +
2 shared (h_E=1536), first layer dense (h_F=12288), vocab 102400.
"""

from repro.core.notation import (AttentionKind, FamilyKind, MLASpec, MlpKind,
                                 MoESpec, ModelSpec)

SPEC = ModelSpec(
    name="deepseek-v2",
    family=FamilyKind.MOE,
    n_layers=60,
    h=5120,
    n_h=128,
    n_kv=128,
    d_head=128,
    h_ff=12288,
    vocab=102400,
    attention=AttentionKind.MLA,
    mlp=MlpKind.SWIGLU,
    mla=MLASpec(d_cq=1536, d_c=512, d_h=128, d_hr=64, d_v=128),
    moe=MoESpec(n_routed=160, n_active=6, n_shared=2, d_ff_expert=1536,
                first_k_dense=1),
    max_seq_len=4096,
)

SMOKE = ModelSpec(
    name="deepseek-v2-smoke",
    family=FamilyKind.MOE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=32,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.MLA,
    mlp=MlpKind.SWIGLU,
    mla=MLASpec(d_cq=96, d_c=64, d_h=32, d_hr=16, d_v=32),
    moe=MoESpec(n_routed=4, n_active=2, n_shared=2, d_ff_expert=128,
                first_k_dense=1),
    max_seq_len=512,
)
