"""Qwen1.5-MoE-A2.7B [arXiv:2407.10671 §2; HF ``qwen2_moe``] — 24L
d_model=2048 16H (MHA kv=16, qkv bias), vocab 151936; MoE 60 experts top-4
with a 4×-wide always-on shared expert (shared_expert_intermediate 5632 =
4 × moe_intermediate 1408), every layer MoE.

The repo's "qwen2-moe-shaped" probe arch: small enough to compile per-rank
dry-run programs quickly, yet it exercises every EP-relevant feature at
once — many routed experts (60, divisible by small TP degrees), a shared
expert on the ETP path, and softmax top-k routing — which is why the
``dryrun --pp --tp --ep`` dispatch-buffer validation pair runs on it.
"""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind, MoESpec,
                                 ModelSpec)

SPEC = ModelSpec(
    name="qwen2-moe-a2.7b",
    family=FamilyKind.MOE,
    n_layers=24,
    h=2048,
    n_h=16,
    n_kv=16,
    d_head=128,
    h_ff=0,                      # every layer is MoE
    vocab=151936,
    attention=AttentionKind.MHA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=60, n_active=4, n_shared=4, d_ff_expert=1408,
                first_k_dense=0),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=8192,
)

SMOKE = ModelSpec(
    name="qwen2-moe-smoke",
    family=FamilyKind.MOE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=64,
    h_ff=0,
    vocab=512,
    attention=AttentionKind.MHA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=4, n_active=2, n_shared=1, d_ff_expert=128,
                first_k_dense=0),
    qkv_bias=True,
    max_seq_len=512,
)
