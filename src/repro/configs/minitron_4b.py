"""Minitron-4B [arXiv:2407.14679] — pruned Nemotron-4:
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
Nemotron uses squared-ReLU 2-matrix MLP; modelled as the 2-matrix GELU kind
(same parameter/activation geometry)."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec)

SPEC = ModelSpec(
    name="minitron-4b",
    family=FamilyKind.DENSE,
    n_layers=32,
    h=3072,
    n_h=24,
    n_kv=8,
    d_head=128,
    h_ff=9216,
    vocab=256000,
    attention=AttentionKind.GQA,
    mlp=MlpKind.GELU,
    max_seq_len=4096,
)

SMOKE = ModelSpec(
    name="minitron-smoke",
    family=FamilyKind.DENSE,
    n_layers=2,
    h=256,
    n_h=8,
    n_kv=4,
    d_head=32,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.GQA,
    mlp=MlpKind.GELU,
    max_seq_len=512,
)
