"""Gemma-7B [arXiv:2403.08295] — 28L d_model=3072 16H (GQA kv=16, i.e. MHA)
d_ff=24576 GeGLU, head_dim=256, vocab=256000, tied embeddings."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec)

SPEC = ModelSpec(
    name="gemma-7b",
    family=FamilyKind.DENSE,
    n_layers=28,
    h=3072,
    n_h=16,
    n_kv=16,
    d_head=256,
    h_ff=24576,
    vocab=256000,
    attention=AttentionKind.MHA,
    mlp=MlpKind.GEGLU,
    tie_embeddings=True,
    max_seq_len=8192,
)

SMOKE = ModelSpec(
    name="gemma-7b-smoke",
    family=FamilyKind.DENSE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=64,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.MHA,
    mlp=MlpKind.GEGLU,
    tie_embeddings=True,
    max_seq_len=512,
)
