"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head architecture:
32L d_model=1600 25H (GQA kv=5, head_dim 64) d_ff=5504 vocab=32001,
ssm_state=16.  Attention and SSM (mamba-flavoured) heads run IN PARALLEL in
every layer; outputs are normalised and averaged (merge_norm)."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec, SSMSpec)

SPEC = ModelSpec(
    name="hymba-1.5b",
    family=FamilyKind.HYBRID,
    n_layers=32,
    h=1600,
    n_h=25,
    n_kv=5,
    d_head=64,
    h_ff=5504,
    vocab=32001,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    ssm=SSMSpec(state_dim=16, n_ssm_heads=25, ssm_expand=1),
    max_seq_len=8192,
)

SMOKE = ModelSpec(
    name="hymba-smoke",
    family=FamilyKind.HYBRID,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=2,
    d_head=64,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    ssm=SSMSpec(state_dim=16, n_ssm_heads=4, ssm_expand=1),
    max_seq_len=512,
)
