"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder:
4L decoder (+4L encoder, n_ctx=1500) d_model=384 6H d_ff=1536 vocab=51865,
GELU MLP, tied decoder embeddings.  The mel-spectrogram + conv frontend is a
STUB: input_specs supplies precomputed frame embeddings (carve-out per task).
"""

from repro.core.notation import (AttentionKind, EncoderSpec, FamilyKind,
                                 MlpKind, ModelSpec)

SPEC = ModelSpec(
    name="whisper-tiny",
    family=FamilyKind.AUDIO,
    n_layers=4,
    h=384,
    n_h=6,
    n_kv=6,
    d_head=64,
    h_ff=1536,
    vocab=51865,
    attention=AttentionKind.MHA,
    mlp=MlpKind.GELU,
    encoder=EncoderSpec(n_layers=4, n_ctx=1500),
    tie_embeddings=True,
    max_seq_len=448,
)

SMOKE = ModelSpec(
    name="whisper-smoke",
    family=FamilyKind.AUDIO,
    n_layers=2,
    h=128,
    n_h=4,
    n_kv=4,
    d_head=32,
    h_ff=256,
    vocab=512,
    attention=AttentionKind.MHA,
    mlp=MlpKind.GELU,
    encoder=EncoderSpec(n_layers=2, n_ctx=64),
    tie_embeddings=True,
    max_seq_len=128,
)
