"""RWKV6-1.6B "Finch" [arXiv:2404.05892] — attention-free SSM:
24L d_model=2048, channel-mix d_ff=7168, vocab=65536; 32 recurrent heads of
64 with data-dependent decay.  Channel-mix modelled as the 2-matrix MLP kind
(receptance gating folded into the time-mix g gate — DESIGN.md §7).
Runs long_500k natively: O(1)-in-context recurrent state."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec, SSMSpec)

SPEC = ModelSpec(
    name="rwkv6-1.6b",
    family=FamilyKind.SSM,
    n_layers=24,
    h=2048,
    n_h=32,          # recurrent heads (no attention)
    n_kv=32,
    d_head=64,
    h_ff=7168,
    vocab=65536,
    attention=AttentionKind.NONE,
    mlp=MlpKind.GELU,
    ssm=SSMSpec(state_dim=64, n_ssm_heads=32, ssm_expand=1),
    max_seq_len=1 << 20,
)

SMOKE = ModelSpec(
    name="rwkv6-smoke",
    family=FamilyKind.SSM,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=64,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.NONE,
    mlp=MlpKind.GELU,
    ssm=SSMSpec(state_dim=64, n_ssm_heads=4, ssm_expand=1),
    max_seq_len=512,
)
