"""DeepSeek-V3 — the paper's reference model [arXiv:2412.19437, paper Table 1].

671B total / ~37B active; 61 layers, MLA (d_c=512, d_cq=1536), 256 routed
experts top-8 + 1 shared, first 3 layers dense FFN (h_F=18432).
"""

from repro.core.notation import (AttentionKind, FamilyKind, MLASpec, MlpKind,
                                 MoESpec, ModelSpec)

SPEC = ModelSpec(
    name="deepseek-v3",
    family=FamilyKind.MOE,
    n_layers=61,
    h=7168,
    n_h=128,
    n_kv=128,
    d_head=128,
    h_ff=18432,
    vocab=129280,
    attention=AttentionKind.MLA,
    mlp=MlpKind.SWIGLU,
    mla=MLASpec(d_cq=1536, d_c=512, d_h=128, d_hr=64, d_v=128),
    moe=MoESpec(n_routed=256, n_active=8, n_shared=1, d_ff_expert=2048,
                first_k_dense=3),
    rope_theta=10000.0,
    max_seq_len=4096,
    notes="paper reference config (Table 1)",
)

SMOKE = ModelSpec(
    name="deepseek-v3-smoke",
    family=FamilyKind.MOE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=4,
    d_head=32,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.MLA,
    mlp=MlpKind.SWIGLU,
    mla=MLASpec(d_cq=96, d_c=64, d_h=32, d_hr=16, d_v=32),
    moe=MoESpec(n_routed=4, n_active=2, n_shared=1, d_ff_expert=128,
                first_k_dense=1),
    max_seq_len=512,
)
