"""Qwen2-VL-72B [arXiv:2409.12191] — VLM decoder backbone:
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, QKV bias,
M-RoPE (reduces to 1-D RoPE under the stubbed vision frontend — DESIGN.md §4).
Vision tower (ViT-675M) is a stub: input_specs supplies patch embeddings."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec)

SPEC = ModelSpec(
    name="qwen2-vl-72b",
    family=FamilyKind.VLM,
    n_layers=80,
    h=8192,
    n_h=64,
    n_kv=8,
    d_head=128,
    h_ff=29568,
    vocab=152064,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    qkv_bias=True,
    rope_theta=1e6,
    max_seq_len=32768,
)

SMOKE = ModelSpec(
    name="qwen2-vl-smoke",
    family=FamilyKind.VLM,
    n_layers=2,
    h=256,
    n_h=8,
    n_kv=2,
    d_head=32,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    qkv_bias=True,
    max_seq_len=512,
)
