"""Gemma-2B [arXiv:2403.08295] — 18L d_model=2048 8H MQA (kv=1) d_ff=16384
GeGLU, head_dim=256, vocab=256000, tied embeddings, (1+scale) RMSNorm,
sqrt(h)-scaled embeddings."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec)

SPEC = ModelSpec(
    name="gemma-2b",
    family=FamilyKind.DENSE,
    n_layers=18,
    h=2048,
    n_h=8,
    n_kv=1,
    d_head=256,
    h_ff=16384,
    vocab=256000,
    attention=AttentionKind.MQA,
    mlp=MlpKind.GEGLU,
    tie_embeddings=True,
    max_seq_len=8192,
)

SMOKE = ModelSpec(
    name="gemma-2b-smoke",
    family=FamilyKind.DENSE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=1,
    d_head=64,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.MQA,
    mlp=MlpKind.GEGLU,
    tie_embeddings=True,
    max_seq_len=512,
)
