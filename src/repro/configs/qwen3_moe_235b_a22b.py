"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — 94L d_model=4096
64H (GQA kv=4) expert d_ff=1536 vocab=151936; MoE 128 experts top-8, no
shared experts, every layer MoE."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind, MoESpec,
                                 ModelSpec)

SPEC = ModelSpec(
    name="qwen3-moe-235b-a22b",
    family=FamilyKind.MOE,
    n_layers=94,
    h=4096,
    n_h=64,
    n_kv=4,
    d_head=128,
    h_ff=0,                      # all layers MoE
    vocab=151936,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=128, n_active=8, n_shared=0, d_ff_expert=1536,
                first_k_dense=0),
    rope_theta=1e6,
    max_seq_len=32768,
)

SMOKE = ModelSpec(
    name="qwen3-moe-smoke",
    family=FamilyKind.MOE,
    n_layers=2,
    h=256,
    n_h=8,
    n_kv=2,
    d_head=32,
    h_ff=0,
    vocab=512,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    moe=MoESpec(n_routed=4, n_active=2, n_shared=0, d_ff_expert=128,
                first_k_dense=0),
    max_seq_len=512,
)
