"""Qwen2-1.5B [arXiv:2407.10671] — 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936, QKV bias, tied embeddings."""

from repro.core.notation import (AttentionKind, FamilyKind, MlpKind,
                                 ModelSpec)

SPEC = ModelSpec(
    name="qwen2-1.5b",
    family=FamilyKind.DENSE,
    n_layers=28,
    h=1536,
    n_h=12,
    n_kv=2,
    d_head=128,
    h_ff=8960,
    vocab=151936,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    max_seq_len=32768,
)

SMOKE = ModelSpec(
    name="qwen2-smoke",
    family=FamilyKind.DENSE,
    n_layers=2,
    h=256,
    n_h=4,
    n_kv=2,
    d_head=64,
    h_ff=512,
    vocab=512,
    attention=AttentionKind.GQA,
    mlp=MlpKind.SWIGLU,
    qkv_bias=True,
    tie_embeddings=True,
    max_seq_len=512,
)
