"""Version-portable wrappers over jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``)
across the jax releases this repo must run on.  Import it from here so model
and runtime code never hard-codes either spelling.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable

import jax


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_rep: bool = False) -> Callable:
    """``jax.shard_map`` with the replication check disabled by default.

    The executor and MoE all-to-all paths return values whose replication
    across unrelated axes is established by explicit psums, which the static
    checker cannot always verify — matching the seed's ``check_vma=False``.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    kwargs: dict = {}
    params = inspect.signature(impl).parameters
    if "check_vma" in params:
        kwargs["check_vma"] = check_rep
    elif "check_rep" in params:
        kwargs["check_rep"] = check_rep
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **kwargs)
