"""Manual tensor-parallel collectives for the pipeline executor.

The 3D executor (``train.pipeline_loop``) runs fully-manual ``shard_map``
over ('pipe', 'data', 'model'): nested GSPMD (``shard_map(auto=...)``) is
not usable on the jax versions this repo targets (the SPMD partitioner
rejects ``ppermute``/``with_sharding_constraint`` inside a partially-manual
body), so TP inside a rank is the classic Megatron construction with the
paired f/g operators spelled out:

* :func:`copy_to_tp`   — Megatron's *f*: identity forward, ``psum`` backward.
  Placed where a replicated activation *enters* a TP-sharded region (QKV
  input, MLP input, the logit projection input, MLA's compressed latents).
* :func:`reduce_from_tp` — Megatron's *g*: ``psum`` forward, identity
  backward.  Placed where partial results *leave* a TP region (attention
  out-projection, MLP/expert down-projection, vocab-parallel reductions).

Why not plain ``jax.lax.psum``: under ``shard_map(check_rep=False)`` jax
cannot prove replication, so it transposes ``psum`` to another ``psum`` —
weight gradients come out ``tp``× too large.  The custom-vjp pairs encode
the replication facts we know by construction.  With f/g placed at every
replicated↔sharded boundary, *every* cotangent in the backward pass is the
exact global cotangent, so all weight gradients (sharded and replicated
leaves alike) are exact locally and need no further model-axis reduction.

Also here: the TP-local ``ModelSpec`` view (:func:`tp_local_spec`) the
executor feeds the unchanged model code (head/ff counts divided by tp so
reshapes line up with weight shards), the loud divisibility guard
(:func:`check_tp_supported`), and the vocab-parallel embedding / softmax
cross-entropy (:func:`embed_tp` / :func:`ce_sum_tp`) used by the first /
last model chunk.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.notation import AttentionKind, ModelSpec, tp_violations

TP_AXIS = "model"


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def copy_to_tp(x: jnp.ndarray, axis: str = TP_AXIS) -> jnp.ndarray:
    """Identity forward; all-reduce (psum over ``axis``) backward."""
    return x


def _copy_fwd(x, axis):
    return x, None


def _copy_bwd(axis, _, ct):
    return (jax.lax.psum(ct, axis),)


copy_to_tp.defvjp(_copy_fwd, _copy_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def reduce_from_tp(x: jnp.ndarray, axis: str = TP_AXIS) -> jnp.ndarray:
    """All-reduce (psum over ``axis``) forward; identity backward."""
    return jax.lax.psum(x, axis)


def _reduce_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _reduce_bwd(axis, _, ct):
    return (ct,)


reduce_from_tp.defvjp(_reduce_fwd, _reduce_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _pmax_stopgrad(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Cross-shard max with zero gradient — the log-sum-exp stabilizer
    (``pmax`` has no jax differentiation rule; the max-shift term cancels
    analytically, so a zero cotangent is exact)."""
    return jax.lax.pmax(x, axis)


def _pmax_fwd(x, axis):
    return jax.lax.pmax(x, axis), None


def _pmax_bwd(axis, _, ct):
    return (jnp.zeros_like(ct),)


_pmax_stopgrad.defvjp(_pmax_fwd, _pmax_bwd)


# ---------------------------------------------------------------------------
# TP-local model view + loud divisibility guard
# ---------------------------------------------------------------------------

def check_tp_supported(spec: ModelSpec, tp: int) -> None:
    """Executor guard: manual TP assumes every sharded dim divides exactly
    (no silent replicate-fallback — the manual psums would double-count)."""
    bad = tp_violations(spec, tp)
    if bad:
        raise ValueError(
            f"{spec.name}: tp={tp} does not divide {', '.join(bad)}; the "
            f"pipeline executor's manual TP requires exact divisibility "
            f"(the GSPMD dry-run path replicates indivisible dims instead)")


def tp_local_spec(spec: ModelSpec, tp: int) -> ModelSpec:
    """The per-shard view of ``spec`` under TP degree ``tp``: head and ff
    counts divided so the unchanged model code's reshapes line up with the
    'model'-axis weight shards.  MoE experts shard their *ff* dim (the
    paper's ETP knob — every shard holds all experts, 1/tp of each), so the
    router and dispatch stay replicated and deterministic across shards."""
    if tp <= 1:
        return spec
    check_tp_supported(spec, tp)
    kw = dict(n_h=spec.n_h // tp)
    if spec.attention not in (AttentionKind.NONE, AttentionKind.MLA):
        kw["n_kv"] = spec.n_kv // tp
    if spec.h_ff:
        kw["h_ff"] = spec.h_ff // tp
    if spec.is_moe:
        kw["moe"] = dataclasses.replace(
            spec.moe, d_ff_expert=spec.moe.d_ff_expert // tp)
    return dataclasses.replace(spec, **kw)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding and cross-entropy (rows/columns on the TP axis)
# ---------------------------------------------------------------------------

def embed_tp(w_local: jnp.ndarray, tokens: jnp.ndarray, *,
             axis: str = TP_AXIS, scale_by_dim: bool = False,
             h: int = 0) -> jnp.ndarray:
    """Row-sharded embedding lookup: each shard gathers the rows it owns
    (shard i holds vocab rows [i·v_loc, (i+1)·v_loc)), zeros the rest, and
    the partial results are summed.  Backward scatters the exact cotangent
    into the owning shard's rows only."""
    v_loc = w_local.shape[0]
    off = jax.lax.axis_index(axis) * v_loc
    idx = tokens - off
    ok = (idx >= 0) & (idx < v_loc)
    x = jnp.take(w_local, jnp.clip(idx, 0, v_loc - 1), axis=0)
    x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
    x = reduce_from_tp(x, axis)
    if scale_by_dim:
        x = x * jnp.asarray(h ** 0.5, x.dtype)
    return x


def ce_sum_tp(logits_local: jnp.ndarray, tokens: jnp.ndarray,
              mask: jnp.ndarray, *, axis: str = TP_AXIS) -> jnp.ndarray:
    """Unnormalized next-token CE sum from column-sharded logits
    (``logits_local``: (b, s, v_loc) = shard's contiguous vocab columns).

    Distributed log-sum-exp: global max via ``pmax`` (stop-gradient, the
    standard stabilizer), exp-sums and the gold logit assembled with
    :func:`reduce_from_tp` so the backward pass hands each shard the exact
    cotangent for its local columns.  Matches the pp=1 ``_ce_sum`` to fp32
    round-off."""
    targets = tokens[:, 1:]
    lg = logits_local[:, :-1].astype(jnp.float32)
    v_loc = lg.shape[-1]
    gmax = _pmax_stopgrad(jnp.max(lg, axis=-1), axis)
    sumexp = reduce_from_tp(jnp.sum(jnp.exp(lg - gmax[..., None]), axis=-1),
                            axis)
    logz = jnp.log(sumexp) + gmax
    idx = targets - jax.lax.axis_index(axis) * v_loc
    ok = (idx >= 0) & (idx < v_loc)
    gold_l = jnp.take_along_axis(
        lg, jnp.clip(idx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    gold = reduce_from_tp(jnp.where(ok, gold_l, 0.0), axis)
    return jnp.sum((logz - gold) * mask)
