"""Manual tensor-parallel collectives for the pipeline executor.

The 3D executor (``train.pipeline_loop``) runs fully-manual ``shard_map``
over ('pipe', 'data', 'model'): nested GSPMD (``shard_map(auto=...)``) is
not usable on the jax versions this repo targets (the SPMD partitioner
rejects ``ppermute``/``with_sharding_constraint`` inside a partially-manual
body), so TP inside a rank is the classic Megatron construction with the
paired f/g operators spelled out:

* :func:`copy_to_tp`   — Megatron's *f*: identity forward, ``psum`` backward.
  Placed where a replicated activation *enters* a TP-sharded region (QKV
  input, MLP input, the logit projection input, MLA's compressed latents).
* :func:`reduce_from_tp` — Megatron's *g*: ``psum`` forward, identity
  backward.  Placed where partial results *leave* a TP region (attention
  out-projection, MLP/expert down-projection, vocab-parallel reductions).

With sequence parallelism on (``make_pipeline_train_step(..., sp=True)``,
degree tied to tp — the paper's SP column) the residual stream lives
*seq-sharded* across the same 'model' axis and the f/g pair is replaced by
its SP counterparts (Megatron's ğ and its dual):

* :func:`gather_from_sp` — ğ: all-gather along the sharded token dim
  forward (the TP region sees the full sequence), reduce-scatter backward
  (each shard gets the exact summed cotangent for its seq chunk).
* :func:`scatter_to_sp` — ğ's dual: reduce-scatter forward (the psum of
  ``reduce_from_tp`` fused with re-sharding the output sequence),
  all-gather backward.

LayerNorm inputs, residuals and boundary activations then cost 1/sp of
their replicated bytes — exactly the ``/sp`` divisor the paper's Table 10
applies to sequence-resident terms.  The price is Megatron's known grad
asymmetry: weights consumed *inside* the seq-sharded region (the norm
scales, the MoE router) see only their shard's tokens, so their local
gradients are seq-partial and the executor completes them with one
``psum`` over 'model' after the tick loop (``train.pipeline_loop``).

Why not plain ``jax.lax.psum``: under ``shard_map(check_rep=False)`` jax
cannot prove replication, so it transposes ``psum`` to another ``psum`` —
weight gradients come out ``tp``× too large.  The custom-vjp pairs encode
the replication facts we know by construction.  With f/g placed at every
replicated↔sharded boundary, *every* cotangent in the backward pass is the
exact global cotangent, so all weight gradients (sharded and replicated
leaves alike) are exact locally and need no further model-axis reduction.

Also here: the TP-local ``ModelSpec`` view (:func:`tp_local_spec`) the
executor feeds the unchanged model code (head/ff counts divided by tp so
reshapes line up with weight shards), the loud divisibility guard
(:func:`check_tp_supported`), and the vocab-parallel embedding / softmax
cross-entropy (:func:`embed_tp` / :func:`ce_sum_tp`) used by the first /
last model chunk.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.notation import AttentionKind, ModelSpec, tp_violations

TP_AXIS = "model"


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def copy_to_tp(x: jnp.ndarray, axis: str = TP_AXIS) -> jnp.ndarray:
    """Identity forward; all-reduce (psum over ``axis``) backward."""
    return x


def _copy_fwd(x, axis):
    return x, None


def _copy_bwd(axis, _, ct):
    return (jax.lax.psum(ct, axis),)


copy_to_tp.defvjp(_copy_fwd, _copy_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def reduce_from_tp(x: jnp.ndarray, axis: str = TP_AXIS) -> jnp.ndarray:
    """All-reduce (psum over ``axis``) forward; identity backward."""
    return jax.lax.psum(x, axis)


def _reduce_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _reduce_bwd(axis, _, ct):
    return (ct,)


reduce_from_tp.defvjp(_reduce_fwd, _reduce_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _pmax_stopgrad(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Cross-shard max with zero gradient — the log-sum-exp stabilizer
    (``pmax`` has no jax differentiation rule; the max-shift term cancels
    analytically, so a zero cotangent is exact)."""
    return jax.lax.pmax(x, axis)


def _pmax_fwd(x, axis):
    return jax.lax.pmax(x, axis), None


def _pmax_bwd(axis, _, ct):
    return (jnp.zeros_like(ct),)


_pmax_stopgrad.defvjp(_pmax_fwd, _pmax_bwd)


# ---------------------------------------------------------------------------
# Sequence-parallel boundary operators (Megatron's ğ and its dual)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gather_from_sp(x: jnp.ndarray, axis: str = TP_AXIS,
                   dim: int = 1) -> jnp.ndarray:
    """Megatron SP's ğ: all-gather the seq-sharded tensor along ``dim``
    forward (every shard sees the full sequence at the entry of a TP
    region); reduce-scatter the cotangent backward, which both sums the
    per-shard partial cotangents (the job ``copy_to_tp``'s psum-bwd did)
    and re-shards the sequence."""
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True)


def _gather_sp_fwd(x, axis, dim):
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True), None


def _gather_sp_bwd(axis, dim, _, ct):
    return (jax.lax.psum_scatter(ct, axis, scatter_dimension=dim,
                                 tiled=True),)


gather_from_sp.defvjp(_gather_sp_fwd, _gather_sp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def scatter_to_sp(x: jnp.ndarray, axis: str = TP_AXIS,
                  dim: int = 1) -> jnp.ndarray:
    """ğ's dual: reduce-scatter along ``dim`` forward where partial results
    leave a TP region (``reduce_from_tp``'s psum fused with re-sharding the
    output sequence); all-gather the seq-sharded cotangent backward (every
    shard's sharded weights need the full-sequence cotangent)."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


def _scatter_sp_fwd(x, axis, dim):
    return jax.lax.psum_scatter(x, axis, scatter_dimension=dim,
                                tiled=True), None


def _scatter_sp_bwd(axis, dim, _, ct):
    return (jax.lax.all_gather(ct, axis, axis=dim, tiled=True),)


scatter_to_sp.defvjp(_scatter_sp_fwd, _scatter_sp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def pmean_sp(x: jnp.ndarray, axis: str = TP_AXIS) -> jnp.ndarray:
    """Cross-shard mean of per-shard token statistics (the MoE router's
    load-balance means under SP, where each shard routes a disjoint seq
    chunk).  Forward ``pmean``; backward hands each shard ``ct / sp`` —
    the exact chain factor ∂mean/∂(shard summand), with no psum because
    the downstream consumer (the aux loss) is replicated, so every shard
    already carries the identical cotangent.  The seq-partial router
    gradients this produces are completed by the executor's post-loop
    'model'-axis psum (see ``train.pipeline_loop``)."""
    return jax.lax.pmean(x, axis)


def _pmean_sp_fwd(x, axis):
    return jax.lax.pmean(x, axis), None


def _pmean_sp_bwd(axis, _, ct):
    return (ct / jax.lax.psum(1, axis),)


pmean_sp.defvjp(_pmean_sp_fwd, _pmean_sp_bwd)


# ---------------------------------------------------------------------------
# ZeRO-3 gather-on-use boundary operator (all-gather over the DP axes)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gather_params(x: jnp.ndarray, axes=("data",), dim: int = 0) -> jnp.ndarray:
    """ZeRO-3's gather-on-use operator: the DP analogue of ğ, applied to
    *weights* instead of activations.  Forward all-gathers a rank's
    1/dp parameter shard along ``dim`` over the per-stage DP group
    (``axes`` — a tuple so ('pod','data') meshes work), so the tick's
    compute sees the full chunk weights; the gathered copy is a transient
    that dies with the tick.  Backward reduce-scatters the weight
    cotangent, which in one collective (a) sums the per-DP-replica grad
    contributions (the job the executor's post-loop data psum does for
    replicated leaves) and (b) re-shards the result onto the owner —
    so gradients, like the ZeRO-2 spec requires, only ever materialize
    shard-sized.  Same check_rep=False rationale as f/g: a plain
    all_gather would transpose to psum_scatter of *already-summed*
    cotangents only if jax could prove the forward input was unreplicated
    per-shard data, which it can't here."""
    return jax.lax.all_gather(x, axes, axis=dim, tiled=True)


def _gather_params_fwd(x, axes, dim):
    return jax.lax.all_gather(x, axes, axis=dim, tiled=True), None


def _gather_params_bwd(axes, dim, _, ct):
    return (jax.lax.psum_scatter(ct, axes, scatter_dimension=dim,
                                 tiled=True),)


gather_params.defvjp(_gather_params_fwd, _gather_params_bwd)


# ---------------------------------------------------------------------------
# Expert-parallel token boundary operators (a2a dispatch over 'model')
# ---------------------------------------------------------------------------
#
# With EP on (``make_pipeline_train_step(..., ep=tp)``) the MoE layer's
# routed experts live sharded on their *expert* dim across 'model' and the
# token exchange is an explicit ``lax.all_to_all`` (models.moe's EP path).
# Under SP the residual already arrives token-sharded, so EP composes with
# no extra operator; without SP the residual is replicated across 'model'
# and the EP region is bracketed by this pair — the token-dim analogue of
# copy_to_tp / reduce_from_tp, encoding the same replication facts the
# check_rep=False shard_map cannot prove:

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def shard_tokens_ep(x: jnp.ndarray, axis: str = TP_AXIS,
                    dim: int = 0) -> jnp.ndarray:
    """EP entry for a token tensor *replicated* across ``axis``: forward
    takes the rank's own 1/ep chunk along ``dim`` (a slice — no collective;
    every rank already holds the full tensor); backward all-gathers the
    per-chunk cotangents, which are exact per token (each token's entire
    downstream path runs on the one rank that owns it), so the assembled
    full cotangent is exact and replicated — the invariant every upstream
    consumer of the replicated residual assumes."""
    n = jax.lax.psum(1, axis)
    chunk = x.shape[dim] // n
    return jax.lax.dynamic_slice_in_dim(
        x, jax.lax.axis_index(axis) * chunk, chunk, axis=dim)


def _shard_ep_fwd(x, axis, dim):
    return shard_tokens_ep(x, axis, dim), None


def _shard_ep_bwd(axis, dim, _, ct):
    return (jax.lax.all_gather(ct, axis, axis=dim, tiled=True),)


shard_tokens_ep.defvjp(_shard_ep_fwd, _shard_ep_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def unshard_tokens_ep(x: jnp.ndarray, axis: str = TP_AXIS,
                      dim: int = 0) -> jnp.ndarray:
    """EP exit: all-gather the per-rank token chunks along ``dim`` forward
    (the combined MoE output rejoins the replicated residual); backward
    slices the rank's own chunk of the — replicated, exact — cotangent.
    A plain ``all_gather`` would transpose to ``psum_scatter``, which sums
    the ep identical cotangent copies (ep× gradients); the slice encodes
    the replication we know by construction."""
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True)


def _unshard_ep_fwd(x, axis, dim):
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True), None


def _unshard_ep_bwd(axis, dim, _, ct):
    n = jax.lax.psum(1, axis)
    chunk = ct.shape[dim] // n
    return (jax.lax.dynamic_slice_in_dim(
        ct, jax.lax.axis_index(axis) * chunk, chunk, axis=dim),)


unshard_tokens_ep.defvjp(_unshard_ep_fwd, _unshard_ep_bwd)


def check_ep_supported(spec: ModelSpec, tp: int, ep: int, *,
                       tokens_per_rank: Optional[int] = None) -> None:
    """Executor guard for expert parallelism: the a2a dispatch group is the
    whole 'model' axis, so the executor runs ``ep == tp`` (or 1 — the ETP
    path); the expert count must divide exactly (the expert-dim weight
    shard has no replicate-fallback) and without SP the per-rank token
    slice must tile the axis."""
    if ep == 1:
        return
    if not spec.is_moe:
        raise ValueError(f"{spec.name}: ep={ep} needs an MoE model")
    if ep != tp:
        raise ValueError(
            f"{spec.name}: ep={ep} != tp={tp}; the executor's a2a dispatch "
            f"group is the whole 'model' axis, so EP degree is tied to it "
            f"(grouped sub-axis a2a stays estimator-only)")
    if spec.moe.n_routed % ep:
        raise ValueError(
            f"{spec.name}: ep={ep} does not divide n_routed="
            f"{spec.moe.n_routed}; the expert-dim shard requires exact "
            f"divisibility")
    if tokens_per_rank is not None and tokens_per_rank % ep:
        raise ValueError(
            f"{spec.name}: ep={ep} does not divide the per-rank token count "
            f"{tokens_per_rank} (b*s of one microbatch shard); the EP token "
            f"slice has no pad/replicate fallback")


# ---------------------------------------------------------------------------
# TP-local model view + loud divisibility guard
# ---------------------------------------------------------------------------

def check_tp_supported(spec: ModelSpec, tp: int) -> None:
    """Executor guard: manual TP assumes every sharded dim divides exactly
    (no silent replicate-fallback — the manual psums would double-count)."""
    bad = tp_violations(spec, tp)
    if bad:
        raise ValueError(
            f"{spec.name}: tp={tp} does not divide {', '.join(bad)}; the "
            f"pipeline executor's manual TP requires exact divisibility "
            f"(the GSPMD dry-run path replicates indivisible dims instead)")


def check_sp_supported(spec: ModelSpec, tp: int, seq_len: int) -> None:
    """Executor guard for sequence parallelism (degree tied to ``tp``):
    the token dim must divide exactly — ``all_gather``/``psum_scatter``
    have no replicate-fallback, and the analytic model's fallback
    (``core.activations._seq_shard_or_warn``) would silently diverge from
    a runtime that padded."""
    if tp <= 1:
        raise ValueError(
            f"{spec.name}: sequence parallelism ties its degree to TP "
            f"(Megatron SP); sp needs a 'model' mesh axis > 1, got tp={tp}")
    bad = tp_violations(spec, tp, sp=tp, seq_len=seq_len)
    if bad:
        raise ValueError(
            f"{spec.name}: sp={tp} not executable: {', '.join(bad)} "
            f"(the boundary all-gather/reduce-scatter pair requires exact "
            f"divisibility)")


def tp_local_spec(spec: ModelSpec, tp: int) -> ModelSpec:
    """The per-shard view of ``spec`` under TP degree ``tp``: head and ff
    counts divided so the unchanged model code's reshapes line up with the
    'model'-axis weight shards.  MoE experts shard their *ff* dim (the
    paper's ETP knob — every shard holds all experts, 1/tp of each), so the
    router and dispatch stay replicated and deterministic across shards."""
    if tp <= 1:
        return spec
    check_tp_supported(spec, tp)
    kw = dict(n_h=spec.n_h // tp)
    if spec.attention not in (AttentionKind.NONE, AttentionKind.MLA):
        kw["n_kv"] = spec.n_kv // tp
    if spec.h_ff:
        kw["h_ff"] = spec.h_ff // tp
    if spec.is_moe:
        kw["moe"] = dataclasses.replace(
            spec.moe, d_ff_expert=spec.moe.d_ff_expert // tp)
    return dataclasses.replace(spec, **kw)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding and cross-entropy (rows/columns on the TP axis)
# ---------------------------------------------------------------------------

def embed_tp(w_local: jnp.ndarray, tokens: jnp.ndarray, *,
             axis: str = TP_AXIS, scale_by_dim: bool = False,
             h: int = 0, sp: bool = False) -> jnp.ndarray:
    """Row-sharded embedding lookup: each shard gathers the rows it owns
    (shard i holds vocab rows [i·v_loc, (i+1)·v_loc)), zeros the rest, and
    the partial results are summed.  Backward scatters the exact cotangent
    into the owning shard's rows only.

    ``sp`` fuses the partial-sum with sequence sharding: the psum becomes
    a reduce-scatter over the token dim, so the residual stream leaves the
    embedding already seq-sharded; backward all-gathers the cotangent, so
    each shard's rows still receive the exact full-sequence gradient."""
    v_loc = w_local.shape[0]
    off = jax.lax.axis_index(axis) * v_loc
    idx = tokens - off
    ok = (idx >= 0) & (idx < v_loc)
    x = jnp.take(w_local, jnp.clip(idx, 0, v_loc - 1), axis=0)
    x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
    x = scatter_to_sp(x, axis, 1) if sp else reduce_from_tp(x, axis)
    if scale_by_dim:
        x = x * jnp.asarray(h ** 0.5, x.dtype)
    return x


def ce_sum_tp(logits_local: jnp.ndarray, tokens: jnp.ndarray,
              mask: jnp.ndarray, *, axis: str = TP_AXIS) -> jnp.ndarray:
    """Unnormalized next-token CE sum from column-sharded logits
    (``logits_local``: (b, s, v_loc) = shard's contiguous vocab columns).

    Distributed log-sum-exp: global max via ``pmax`` (stop-gradient, the
    standard stabilizer), exp-sums and the gold logit assembled with
    :func:`reduce_from_tp` so the backward pass hands each shard the exact
    cotangent for its local columns.  Matches the pp=1 ``_ce_sum`` to fp32
    round-off."""
    targets = tokens[:, 1:]
    lg = logits_local[:, :-1].astype(jnp.float32)
    v_loc = lg.shape[-1]
    gmax = _pmax_stopgrad(jnp.max(lg, axis=-1), axis)
    sumexp = reduce_from_tp(jnp.sum(jnp.exp(lg - gmax[..., None]), axis=-1),
                            axis)
    logz = jnp.log(sumexp) + gmax
    idx = targets - jax.lax.axis_index(axis) * v_loc
    ok = (idx >= 0) & (idx < v_loc)
    gold_l = jnp.take_along_axis(
        lg, jnp.clip(idx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    gold = reduce_from_tp(jnp.where(ok, gold_l, 0.0), axis)
    return jnp.sum((logz - gold) * mask)
