from .axes import (axis_rules, logical_constraint, logical_sharding,
                   param_partition_spec, current_mesh)

__all__ = ["axis_rules", "logical_constraint", "logical_sharding",
           "param_partition_spec", "current_mesh"]
