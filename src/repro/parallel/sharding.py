"""Parameter/state sharding rules: pytree path → logical axes → PartitionSpec.

Implements the paper's §3 partitioning on the TPU mesh:
  * Megatron-TP of attention & dense MLP  → ``model`` axis
  * MLA: W^UQ/W^UK/W^UV/W^O split, W^DQ/W^DKV/W^QR/W^KR replicated (§3.2)
  * EP: routed experts sharded on the expert dim; shared expert replicated
    (§3.3); ETP=1 → expert matrices unsplit internally
  * ZeRO (§4): optimizer state (os), gradients (os+g), parameters
    (os+g+params) additionally sharded across the data(+pod) axes — the
    GSPMD equivalent of DeepSpeed's DP-group partitioning.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.parallel_config import ZeROStage
from .axes import DEFAULT_RULES, param_partition_spec

PyTree = Any

# leaf-name → logical axes (stacked-layer leading dim handled separately)
_ATTN_RULES = {
    "wq": ("embed", "qkv"), "wk": ("embed", "qkv"), "wv": ("embed", "qkv"),
    "wo": ("qkv", "embed"),
    "bq": ("qkv",), "bk": ("qkv",), "bv": ("qkv",),
    "w_dq": ("embed", None), "w_uq": (None, "qkv"), "w_qr": (None, "qkv"),
    "w_dkv": ("embed", None), "w_uk": (None, "qkv"), "w_uv": (None, "qkv"),
    "w_kr": ("embed", None), "w_o": ("qkv", "embed"),
}
_SSM_RULES = {
    "w_r": ("embed", "ff"), "w_k": ("embed", "ff"), "w_v": ("embed", "ff"),
    "w_g": ("embed", "ff"), "w_o": ("ff", "embed"),
    "decay_a": ("embed", None), "decay_b": (None, "ff"),
    "u": ("ff",), "mu": (None, None), "conv": (None, "ff"),
}
_MLP_RULES = {
    "gate": ("embed", "ff"), "up": ("embed", "ff"), "down": ("ff", "embed"),
    "fc1": ("embed", "ff"), "fc2": ("ff", "embed"),
}
_MOE_RULES = {
    "router": ("embed", None),
    "we_gate": ("expert", None, "expert_ff"),
    "we_up": ("expert", None, "expert_ff"),
    "we_down": ("expert", "expert_ff", None),
}


def _leaf_axes(path: Tuple[str, ...], ndim: int) -> Tuple[Optional[str], ...]:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    parents = set(keys[:-1])

    if "embed" in parents:
        base: Tuple[Optional[str], ...] = ("vocab", "embed")
    elif "head" in parents:
        base = ("embed", "vocab")
    elif name == "scale":                      # any norm
        base = ("embed",)
    elif "moe" in parents and name in _MOE_RULES:
        base = _MOE_RULES[name]
    elif ("shared" in parents or "mlp" in parents) and name in _MLP_RULES:
        base = _MLP_RULES[name]
    elif "ssm" in parents and name in _SSM_RULES:
        base = _SSM_RULES[name]
    elif name in _ATTN_RULES:                  # attn / xattn
        base = _ATTN_RULES[name]
    elif name in _MLP_RULES:
        base = _MLP_RULES[name]
    else:
        base = (None,) * ndim
    # stacked layer groups carry a leading layer dim
    if ndim == len(base) + 1:
        return (None,) + tuple(base)
    if ndim == len(base):
        return tuple(base)
    # e.g. vmapped extra dims: pad with None in front
    return (None,) * (ndim - len(base)) + tuple(base)


def _drop_indivisible(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Replicate any dim whose size isn't divisible by its mesh-axes product
    (e.g. hymba's vocab=32001)."""
    entries = []
    for dim, e in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if e is None:
            entries.append(None)
            continue
        ns = (e,) if isinstance(e, str) else tuple(e)
        size = int(np.prod([mesh.shape[n] for n in ns]))
        entries.append(e if dim % size == 0 else None)
    return P(*entries)


def param_specs(params: PyTree, mesh: Mesh, rules=None) -> PyTree:
    """PartitionSpec pytree mirroring ``params`` (abstract or concrete)."""

    def spec_for(path, leaf):
        shape = leaf.shape
        axes = _leaf_axes(path, getattr(leaf, "ndim", len(shape)))
        return _drop_indivisible(
            param_partition_spec(axes, mesh, rules), shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def _dims_ok(shape: Sequence[int], spec: P, mesh: Mesh) -> bool:
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if names is None:
            continue
        ns = (names,) if isinstance(names, str) else names
        size = int(np.prod([mesh.shape[n] for n in ns]))
        if dim % size:
            return False
    return True


def add_dp_axes(spec: P, shape: Sequence[int], mesh: Mesh,
                dp_axes: Sequence[str] = ("pod", "data")) -> P:
    """ZeRO: extend ``spec`` with the data(+pod) axes on the first dimension
    where the result stays legal (divisible, axes unused).  Falls back to the
    original spec when nothing fits (tiny tensors stay replicated — same as
    DeepSpeed's small-tensor handling)."""
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    if not dp_axes:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update((e,) if isinstance(e, str) else e)
    if any(a in used for a in dp_axes):
        return spec
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        existing = () if e is None else ((e,) if isinstance(e, str) else tuple(e))
        ex_size = int(np.prod([mesh.shape[n] for n in existing])) if existing else 1
        if dim % (ex_size * dp_size) == 0:
            entries[i] = tuple(existing) + dp_axes
            return P(*entries)
    return spec


def pipeline_stage_specs(stacked: PyTree, mesh: Mesh, rules=None) -> PyTree:
    """PartitionSpec tree for stage-stacked pipeline params
    (``models.pipeline.stack_pipeline_params``): the leading stage dim maps to
    the ``pipe`` mesh axis; remaining dims follow the usual §3 leaf rules
    (so per-stage TP still applies on meshes that carry a model axis).  ZeRO
    DP-sharding within a stage is unchanged — apply ``add_dp_axes`` on top
    exactly as for pp=1 state."""

    def spec_for(path, leaf):
        axes = _leaf_axes(path, leaf.ndim)
        base = param_partition_spec(axes, mesh, rules)
        entries = list(tuple(base) + (None,) * (leaf.ndim - len(tuple(base))))
        entries[0] = "pipe" if "pipe" in mesh.axis_names else None
        return _drop_indivisible(P(*entries), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, stacked)


def zero3_stage_specs(stacked: PyTree, mesh: Mesh, rules=None,
                      dp_axes: Sequence[str] = ("pod", "data")):
    """ZeRO-3 layout for stage-stacked pipeline params: the
    ``pipeline_stage_specs`` layout with the data(+pod) axes added on the
    first shardable *weight* dim of every leaf, plus a parallel tree of
    gather dims for the executor's gather-on-use collectives.

    Returns ``(specs, dims)`` where ``dims`` holds, per leaf, the
    *stacked-tree* dim index carrying the DP shard, or ``-1`` when the leaf
    stays replicated across DP (tiny tensors with no divisible dim — the
    small-tensor fallback; the executor keeps the plain psum grad-reduce
    for those).  ``-1`` is a sentinel rather than None because None leaves
    vanish from pytrees.

    Dim choice skips the structural dims the executor indexes away before
    use: dim 0 is the pipe stage; for leaves under the top-level "layers"
    key dim 1 is the interleaving chunk (V) dim and dim 2 the scanned
    layer dim — the layer dim *is* shardable (the gather re-assembles it).
    """
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1

    def choice(path, leaf):
        axes = _leaf_axes(path, leaf.ndim)
        base = param_partition_spec(axes, mesh, rules)
        entries = list(tuple(base) + (None,) * (leaf.ndim - len(tuple(base))))
        entries[0] = "pipe" if "pipe" in mesh.axis_names else None
        spec = _drop_indivisible(P(*entries), leaf.shape, mesh)
        entries = list(tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec))))
        if dp_size <= 1:
            return P(*entries), -1
        used = set()
        for e in entries:
            if e is not None:
                used.update((e,) if isinstance(e, str) else e)
        if any(a in used for a in dp_axes):
            return P(*entries), -1
        top = getattr(path[0], "key", getattr(path[0], "name", str(path[0])))
        min_dim = 2 if top == "layers" else 1
        for i in range(min_dim, leaf.ndim):
            e = entries[i]
            existing = () if e is None else (
                (e,) if isinstance(e, str) else tuple(e))
            ex = int(np.prod([mesh.shape[n] for n in existing])) \
                if existing else 1
            if leaf.shape[i] % (ex * dp_size) == 0:
                entries[i] = tuple(existing) + dp_axes
                return P(*entries), i
        return P(*entries), -1

    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: choice(p, l)[0], stacked)
    dims = jax.tree_util.tree_map_with_path(
        lambda p, l: choice(p, l)[1], stacked)
    return specs, dims


def state_shardings(abstract_state, mesh: Mesh, zero: ZeROStage,
                    rules=None):
    """NamedSharding trees for a TrainState (params, master/m/v, step).

    params follow §3 TP/EP rules; {master, m, v} additionally DP-sharded for
    zero >= os; params DP-sharded for os+g+params.
    """
    from repro.optim.adamw import TrainState

    pspecs = param_specs(abstract_state.params, mesh, rules)
    shapes = jax.tree.map(lambda a: a.shape, abstract_state.params)

    def shard(spec_tree, with_dp):
        def one(spec, shape):
            s = add_dp_axes(spec, shape, mesh) if with_dp else spec
            return NamedSharding(mesh, s)
        return jax.tree.map(one, spec_tree, shapes)

    zp = zero == ZeROStage.OS_G_PARAMS
    zo = zero != ZeROStage.NONE
    return TrainState(
        step=NamedSharding(mesh, P()),
        params=shard(pspecs, zp),
        master=shard(pspecs, zo),
        m=shard(pspecs, zo),
        v=shard(pspecs, zo),
    )


def grad_shardings(abstract_params, mesh: Mesh, zero: ZeROStage, rules=None):
    """fp32 gradient-buffer shardings (DP-sharded for zero >= os+g)."""
    pspecs = param_specs(abstract_params, mesh, rules)
    shapes = jax.tree.map(lambda a: a.shape, abstract_params)
    with_dp = zero in (ZeROStage.OS_G, ZeROStage.OS_G_PARAMS)

    def one(spec, shape):
        s = add_dp_axes(spec, shape, mesh) if with_dp else spec
        return NamedSharding(mesh, s)

    return jax.tree.map(one, pspecs, shapes)
