"""Logical-axis sharding (MaxText-style).

Model code annotates tensors with *logical* axis names ("batch", "seq",
"vocab", "heads", "ff", "expert", ...).  A context (mesh + rules) maps the
logical names to physical mesh axes; outside any context the annotations are
no-ops, so the same model runs single-device smoke tests and 512-chip
dry-runs unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

_state = threading.local()

# Default mapping for the production meshes (see launch/mesh.py):
#   single-pod (16,16) axes ("data","model"); multi-pod (2,16,16) adds "pod";
#   pp>1 carves a leading "pipe" axis out of data: (pp, 16/pp, 16).
# The "pod" axis extends data parallelism (DP-major, the paper's DP·EDP
# grouping); "model" carries TP + EP (+ SP for sequence-resident tensors);
# "pipe" holds the stage dim of stage-stacked pipeline params.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,              # dryrun --sp overrides to "model" (Megatron SP)
    "embed": None,            # hidden/residual dim replicated
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",           # fused head*dim columns
    "ff": "model",
    "expert": "model",
    "expert_ff": None,        # ETP axis (ETP=1 in the paper's case study)
    "cache_seq": None,
    "dp_shard": ("pod", "data"),   # ZeRO sharding axis for state pytrees
    "conv": None,
    "lowrank": None,
    "stage": "pipe",          # PP stage dim of stage-stacked pipeline params
}


def _get() -> Tuple[Optional[Mesh], Rules]:
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", DEFAULT_RULES)
    return mesh, rules


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Rules] = None):
    """Activate a mesh + logical-rule mapping for model annotations."""
    prev = getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES)
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        with mesh:
            yield
    finally:
        _state.mesh, _state.rules = prev


def _resolve(axes: Sequence[Optional[str]], mesh: Mesh, rules: Rules) -> P:
    phys = []
    used = set()
    for a in axes:
        if a is None:
            phys.append(None)
            continue
        m = rules.get(a)
        if m is None:
            phys.append(None)
            continue
        names = (m,) if isinstance(m, str) else tuple(m)
        names = tuple(n for n in names if n in mesh.axis_names and n not in used)
        used.update(names)
        phys.append(names if len(names) > 1 else (names[0] if names else None))
    return P(*phys)


def logical_sharding(axes: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[Rules] = None) -> Optional[NamedSharding]:
    m, r = _get()
    mesh = mesh or m
    rules = dict(DEFAULT_RULES, **(rules or {})) if rules else r
    if mesh is None:
        return None
    return NamedSharding(mesh, _resolve(axes, mesh, rules))


def param_partition_spec(axes: Sequence[Optional[str]], mesh: Mesh,
                         rules: Optional[Rules] = None) -> P:
    return _resolve(axes, mesh, dict(DEFAULT_RULES, **(rules or {})))


def logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under an active mesh; identity otherwise."""
    mesh, rules = _get()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(axes, mesh, rules)))


def current_mesh() -> Optional[Mesh]:
    return _get()[0]
