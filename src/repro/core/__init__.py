"""``repro.core`` — the paper's contribution: an analytical device-level
memory model for distributed MoE/dense/SSM training (params, ZeRO states,
activations, buffers) plus a configuration planner built on it."""

from .activations import (layer_activation_bytes, moe_activation_bytes,
                          mla_activation_bytes, one_f1b_in_flight,
                          rank_chunk_layers, schedule_activation_bytes,
                          schedule_in_flight, stage_activation_bytes, table10)
from .memory_model import MemoryEstimate, estimate_memory, fits, kv_cache_bytes
from .notation import (AttentionKind, EncoderSpec, FamilyKind, MLASpec,
                       MlpKind, MoESpec, ModelSpec, SSMSpec, human_bytes,
                       human_count, tp_violations)
from .parallel_config import (BF16_POLICY, FP8_POLICY, PAPER_CONFIG,
                              DTypePolicy, ParallelConfig, RecomputePolicy,
                              ZeROStage)
from .params import (DeviceParams, device_params, max_stage, table3_rows,
                     table4_stages, total_params_paper)
from .planner import (PlanEntry, enumerate_configs, executor_runnable,
                      min_memory_config, plan)
from .schedules import (SCHEDULES, PipelineSchedule, TickOp, make_schedule,
                        n_model_chunks, schedule_placement)
from .steptime import (BubbleStats, StepTimePrediction, bubble_fraction,
                       bubble_stats, exec_ticks, mfu, model_fwd_flops,
                       predict_step_time, step_flops)
from .zero import TrainStateBytes, zero_memory, zero_table

__all__ = [
    "AttentionKind", "BF16_POLICY", "DTypePolicy", "DeviceParams",
    "EncoderSpec", "FP8_POLICY", "FamilyKind", "MLASpec", "MemoryEstimate",
    "MlpKind", "MoESpec", "ModelSpec", "PAPER_CONFIG", "ParallelConfig",
    "RecomputePolicy", "SSMSpec", "TrainStateBytes", "ZeROStage",
    "BubbleStats", "PipelineSchedule", "PlanEntry", "SCHEDULES",
    "StepTimePrediction", "TickOp",
    "bubble_fraction", "bubble_stats",
    "device_params", "enumerate_configs", "estimate_memory", "exec_ticks",
    "executor_runnable", "fits",
    "human_bytes", "human_count", "kv_cache_bytes", "layer_activation_bytes",
    "make_schedule", "max_stage", "mfu", "min_memory_config",
    "mla_activation_bytes", "model_fwd_flops",
    "moe_activation_bytes", "n_model_chunks", "one_f1b_in_flight", "plan",
    "predict_step_time",
    "rank_chunk_layers", "schedule_activation_bytes", "schedule_in_flight",
    "schedule_placement", "stage_activation_bytes", "step_flops", "table10",
    "table3_rows", "table4_stages", "total_params_paper", "tp_violations",
    "zero_memory", "zero_table",
]
