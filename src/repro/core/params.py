"""Parameter counting (paper §2-§3, Tables 3, 4, 6).

Two counting modes exist:

* ``paper mode`` — reproduces the paper's Table 3 row values *exactly*,
  including its quirk of counting MLA's q/kv RMSNorm weights both inside the
  MLA row (187,107,328) and inside the LN row (16,384).  Used by report.py
  and the table benchmarks.
* ``exact mode`` — ``ModelSpec.layer_params`` counts every parameter once;
  used by the runtime validation (matches ``jax.tree`` leaf counts of the
  real model to the parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from .notation import AttentionKind, ModelSpec
from .parallel_config import ParallelConfig, ZeROStage


# ---------------------------------------------------------------------------
# Table 3 — layer-level counting (paper mode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerRow:
    layers: str
    modules: Dict[str, int]     # module name -> parameter count
    per_layer: int              # total per single layer in this group
    n_layers: int

    @property
    def group_total(self) -> int:
        return self.per_layer * self.n_layers


def mla_params_paper(spec: ModelSpec) -> int:
    """MLA row of Table 3: projections + q/kv norms (paper includes them)."""
    return spec.attn_params_per_layer(include_qk_norm=True)


def ln_params_paper(spec: ModelSpec) -> int:
    """LN row of Table 3: 2*h + d_cq + d_c (double-counts the qk norms)."""
    n = 2 * spec.h
    if spec.attention == AttentionKind.MLA:
        n += spec.mla.d_cq + spec.mla.d_c
    return n


def table3_rows(spec: ModelSpec) -> List[LayerRow]:
    """Layer-level rows in the paper's grouping for a DeepSeek-style model."""
    assert spec.is_moe and spec.attention == AttentionKind.MLA, \
        "table3 is defined for the paper's MLA+MoE family"
    mla = mla_params_paper(spec)
    ln = ln_params_paper(spec)
    dense_mlp = spec.dense_mlp_params_per_layer()
    gate = spec.moe.n_routed * spec.h
    experts = 3 * spec.h * spec.moe.d_ff_expert * (spec.moe.n_routed + spec.moe.n_shared)
    emb = spec.embedding_params()
    k = spec.moe.first_k_dense
    l = spec.n_layers

    rows = [
        LayerRow("Layer 0",
                 {"Embedding": emb, "MLA": mla, "MLP": dense_mlp, "LN": ln},
                 emb + mla + dense_mlp + ln, 1),
        LayerRow(f"Layers 1 - {k - 1}",
                 {"MLA": mla, "MLP": dense_mlp, "LN": ln},
                 mla + dense_mlp + ln, k - 1),
        LayerRow(f"Layers {k} - {l - 2}",
                 {"MLA": mla, "Gate": gate, "MoE": experts, "LN": ln},
                 mla + gate + experts + ln, l - 1 - k),
        LayerRow(f"Layer {l - 1}",
                 {"MLA": mla, "Gate": gate, "MoE": experts, "LN": ln, "Head": emb},
                 mla + gate + experts + ln + emb, 1),
    ]
    return rows


def total_params_paper(spec: ModelSpec) -> int:
    return sum(r.group_total for r in table3_rows(spec))


# ---------------------------------------------------------------------------
# Table 4 — pipeline-parallel stage assignment
# ---------------------------------------------------------------------------

def pp_stage_layers(n_layers: int, pp: int) -> List[List[int]]:
    """Paper's PP16 split of 61 layers: 4,4,...,4,1 (embedding-heavy stage 0
    gets the first layers; the lone head layer is stage pp-1).  General rule:
    distribute ceil/floor evenly, front-loaded, with the remainder-1 final
    stage when n_layers % pp != 0, matching the paper's 15*4+1 split."""
    if pp == 1:
        return [list(range(n_layers))]
    base = n_layers // pp
    rem = n_layers % pp
    if rem:
        # front stages get base+? — paper: 61/16 -> 15 stages of 4, 1 stage of 1
        sizes = [base + 1] * rem + [base] * (pp - rem)
        # paper puts the small remainder at the END (stage 15 has 1 layer)
        if base * pp + rem == n_layers and sizes[-1] != 1 and n_layers == 61 and pp == 16:
            sizes = [4] * 15 + [1]
    else:
        sizes = [base] * pp
    # normalize: ensure sum matches
    total = sum(sizes)
    if total != n_layers:
        sizes[-1] += n_layers - total
    out, i = [], 0
    for s in sizes:
        out.append(list(range(i, i + s)))
        i += s
    return out


def layer_params_paper(spec: ModelSpec, layer_idx: int) -> int:
    """Per-layer total in paper mode (incl. emb on layer 0, head on last)."""
    mla = mla_params_paper(spec) if spec.attention == AttentionKind.MLA else \
        spec.attn_params_per_layer()
    ln = ln_params_paper(spec)
    p = mla + ln
    if spec.is_moe and layer_idx in spec.moe_layer_indices():
        p += spec.moe.n_routed * spec.h
        p += 3 * spec.h * spec.moe.d_ff_expert * (spec.moe.n_routed + spec.moe.n_shared)
    else:
        p += spec.dense_mlp_params_per_layer()
    if layer_idx == 0:
        p += spec.embedding_params()
    if layer_idx == spec.n_layers - 1 and not spec.tie_embeddings:
        p += spec.embedding_params()
    return p


@dataclasses.dataclass(frozen=True)
class StageRow:
    stage: int
    layers: List[int]
    params: int


def table4_stages(spec: ModelSpec, pp: int) -> List[StageRow]:
    stages = pp_stage_layers(spec.n_layers, pp)
    return [StageRow(i, ls, sum(layer_params_paper(spec, l) for l in ls))
            for i, ls in enumerate(stages)]


def max_stage(spec: ModelSpec, pp: int) -> StageRow:
    return max(table4_stages(spec, pp), key=lambda r: r.params)


# ---------------------------------------------------------------------------
# Table 6 — static parameters per device under TP/EP/ETP (one PP stage)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Per-device parameter counts of one PP stage, split by gradient-sync
    group (the ZeRO math needs non-expert vs expert separated, paper §4)."""

    norms: int              # replicated across TP
    attn_tp: int            # TP-partitioned attention params (per rank)
    attn_replicated: int    # TP-replicated attention params
    dense_mlp: int          # TP-partitioned dense-MLP params (per rank)
    router: int             # replicated router/gate
    experts: int            # per-EP-rank expert params (incl. shared, / ETP)
    ssm: int                # recurrent-path params (TP-partitioned)
    embed: int              # embedding/head share on this stage (TP-split, vocab dim)

    @property
    def non_expert(self) -> int:
        return (self.norms + self.attn_tp + self.attn_replicated
                + self.dense_mlp + self.ssm + self.embed)

    @property
    def expert(self) -> int:
        return self.router + self.experts

    @property
    def total(self) -> int:
        return self.non_expert + self.expert


def _shard(count: int, tp: int, dim: int) -> int:
    """Per-rank share of ``count`` params whose sharded dim has size ``dim``:
    divide by tp when divisible, else replicate (matching the runtime's
    divisibility fallback — validated against XLA, see EXPERIMENTS.md
    §Validation)."""
    return count // tp if dim % tp == 0 else count


def attn_tp_split(spec: ModelSpec, tp: int) -> Tuple[int, int]:
    """(tp_partitioned_per_rank, replicated) attention params for one layer.

    MLA follows Megatron: W^UQ/W^UK/W^UV/W^O split, W^DQ/W^DKV/W^QR/W^KR
    replicated (paper §3.2).  GQA/MQA: q/k/v/o sharded on the head-columns
    dim when divisible (TPU runtime semantics — columns, not whole heads).
    """
    if spec.attention == AttentionKind.NONE:
        return 0, 0
    if spec.attention == AttentionKind.MLA:
        m = spec.mla
        split = (_shard(m.d_h * spec.n_h * m.d_cq, tp, m.d_h * spec.n_h)
                 + _shard(m.d_h * spec.n_h * m.d_c, tp, m.d_h * spec.n_h)
                 + _shard(m.d_v * spec.n_h * m.d_c, tp, m.d_v * spec.n_h)
                 + _shard(spec.h * m.d_v * spec.n_h, tp, m.d_v * spec.n_h))
        repl = (m.d_cq * spec.h + m.d_c * spec.h
                + m.d_hr * spec.n_h * m.d_cq + m.d_hr * spec.h)
        return split, repl
    qdim = spec.n_h * spec.d_head
    kvdim = spec.n_kv * spec.d_head
    split = (_shard(spec.h * qdim, tp, qdim)          # wq
             + _shard(qdim * spec.h, tp, qdim)        # wo
             + 2 * _shard(spec.h * kvdim, tp, kvdim))  # wk, wv
    if spec.qkv_bias:
        split += _shard(qdim, tp, qdim) + 2 * _shard(kvdim, tp, kvdim)
    return split, 0


def device_params(spec: ModelSpec, cfg: ParallelConfig,
                  stage: int = None,
                  layers: Sequence[int] = None) -> DeviceParams:
    """Static parameters per device for one PP stage (default: the largest
    all-MoE stage, as the paper's §3 case study uses stages 1-14).

    ``layers`` overrides the Table-4 stage row with an explicit layer-id
    list — the schedule-aware path uses it for ranks that hold several
    chunks (interleaved virtual stages; dualpipe's duplicated stages, where
    a layer id appearing twice is counted twice — the 2× parameter cost)."""
    if layers is None:
        stages = table4_stages(spec, cfg.pp)
        if stage is None:
            # paper picks a maximal interior stage (no embedding): stages 1-14
            interior = [r for r in stages if 0 not in r.layers
                        and (spec.n_layers - 1) not in r.layers]
            row = max(interior or stages, key=lambda r: r.params)
        else:
            row = stages[stage]
        layers = row.layers

    norms = attn_tp = attn_repl = dense = router = experts = ssm = embed = 0
    for l in layers:
        norms += spec.norm_params_per_layer()
        if spec.ssm is not None and spec.family.value == "hybrid":
            norms += spec.h                                   # merge_norm
        s, r = attn_tp_split(spec, cfg.tp)
        attn_tp += s
        attn_repl += r
        if spec.encoder is not None:
            # decoder cross-attention: 4 h×h matrices + its norm
            attn_tp += 4 * _shard(spec.h * spec.h, cfg.tp, spec.h)
            norms += spec.h
        if spec.ssm is not None:
            ss = spec.ssm
            d = spec.h * ss.ssm_expand
            proj = 5 * _shard(spec.h * d, cfg.tp, d)
            decay = spec.h * 64 + _shard(64 * d, cfg.tp, d) \
                + _shard(d, cfg.tp, d)
            rest = 6 * spec.h + (ss.conv_kernel * d if ss.conv_kernel else 0)
            ssm += proj + decay + rest
        if spec.is_moe and l in spec.moe_layer_indices():
            router += spec.moe.n_routed * spec.h
            n_local = spec.moe.n_routed // cfg.ep
            per_expert = 3 * spec.h * spec.moe.d_ff_expert // cfg.etp
            # shared experts replicated across EP ranks (paper §3.3)
            experts += (n_local + spec.moe.n_shared) * per_expert
        elif spec.h_ff:
            dense += spec.dense_mlp_params_per_layer() // cfg.tp \
                if spec.h_ff % cfg.tp == 0 else spec.dense_mlp_params_per_layer()
        if l == 0:
            embed += _shard(spec.embedding_params(), cfg.tp, spec.vocab)
        if l == spec.n_layers - 1 and not spec.tie_embeddings:
            embed += _shard(spec.embedding_params(), cfg.tp, spec.vocab)
    # encoder tower (whisper): colocated with the (single-PP-stage) decoder
    if spec.encoder is not None and (0 in layers or cfg.pp == 1):
        per = (4 * _shard(spec.h * spec.h, cfg.tp, spec.h)
               + _shard(spec.mlp_params(spec.h_ff), cfg.tp, spec.h_ff)
               + 2 * spec.h)
        embed += spec.encoder.n_layers * per + spec.h
    return DeviceParams(norms=norms, attn_tp=attn_tp, attn_replicated=attn_repl,
                        dense_mlp=dense, router=router, experts=experts,
                        ssm=ssm, embed=embed)


def device_param_bytes(spec: ModelSpec, cfg: ParallelConfig) -> int:
    d = device_params(spec, cfg)
    per = d.total
    if cfg.zero == ZeROStage.OS_G_PARAMS:
        # ceil: shards are ceil(n/group)-sized, the last rank pads
        per = -(-d.non_expert // cfg.dp) + -(-d.expert // cfg.edp)
    return per * cfg.dtype.weights
