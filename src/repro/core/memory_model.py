"""Full per-device training-memory estimate (the paper's end product).

Composes §2-§6: static parameters, gradients, optimizer states (with ZeRO
and the DP/EDP split), activations (with recomputation policy and PP
in-flight microbatches), temporary communication buffers, and a
fragmentation factor.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .activations import stage_activation_bytes
from .notation import ModelSpec, human_bytes
from .params import device_params
from .parallel_config import ParallelConfig, ZeROStage
from .zero import zero_memory


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    params: int
    grads: int
    optimizer: int
    activations: int
    comm_buffers: int
    fragmentation: int
    # ZeRO-3 gather-on-use working copy: the largest chunk's full bf16
    # params, alive from a tick's all-gather until its grads retire —
    # priced like the zb1p pending-dW ring (transient, but resident at
    # peak).  Zero for every other ZeRO stage and on the paper path.
    gather_transient: int = 0

    @property
    def state_total(self) -> int:
        return self.params + self.grads + self.optimizer

    @property
    def total(self) -> int:
        return (self.state_total + self.activations + self.comm_buffers
                + self.gather_transient + self.fragmentation)

    def breakdown(self) -> Dict[str, int]:
        return {
            "params": self.params,
            "grads": self.grads,
            "optimizer": self.optimizer,
            "activations": self.activations,
            "comm_buffers": self.comm_buffers,
            "gather_transient": self.gather_transient,
            "fragmentation": self.fragmentation,
            "total": self.total,
        }

    def pretty(self) -> str:
        rows = [f"  {k:<14} {human_bytes(v):>12}" for k, v in self.breakdown().items()]
        return "\n".join(rows)


def estimate_memory(spec: ModelSpec, cfg: ParallelConfig, *,
                    stage: Optional[int] = None,
                    in_flight_microbatches: Optional[int] = None,
                    training: bool = True,
                    schedule: Optional[str] = None,
                    n_chunks: int = 1,
                    n_micro: Optional[int] = None,
                    attn_impl: Optional[str] = None) -> MemoryEstimate:
    """Per-device memory estimate for one PP stage.

    ``training=False`` models inference/serving: no grads/optimizer, and the
    'activations' term is the KV-cache / recurrent-state working set.

    ``attn_impl`` (``"naive"`` | ``"flash"``/``"pallas"`` | ``"chunked"``)
    overrides ``cfg.attn_impl`` for this estimate: flash impls drop the
    resident 5·b·n_h·s² score buffers from the AC-None activation stash
    (``activations.FLASH_ATTN_IMPLS``); all other terms are unchanged.

    ``schedule`` (one of ``core.schedules.SCHEDULES``) switches to
    schedule-aware accounting for PP rank ``stage``: activations come from
    the tick simulator's time-resolved in-flight peak
    (``schedule_activation_bytes``), and params/grads/optimizer cover every
    layer chunk the rank holds under that schedule — under ``dualpipe`` each
    rank holds two model chunks, the schedule's 2× parameter cost; under
    ``interleaved`` a rank holds ``n_chunks`` virtual stages.  Under
    ``zb1p`` the activation residency matches 1f1b (B — which runs the
    full chunk vjp — still retires the microbatch), but the grads term
    adds the W stash: between a microbatch's B tick and its deferred W
    tick the executor parks that microbatch's fp32 pending-dW (a full
    copy of the rank's per-layer gradients) in a scan-carried slot ring,
    and the W tick merely flushes it into the accumulator.  Each pending
    microbatch therefore costs one fp32 layer-grad copy, and the ring is
    allocated uniformly across ranks at the schedule-wide peak pendency
    ``max(core.schedules.zb_pending_peak)`` — the memory zero-bubble
    trades for its bubble.  The stash is per-device whole-grad state
    (not ZeRO-shardable: it is flushed before any reduce).  The plain
    ``stage=``/``in_flight_microbatches=`` path is the schedule-unaware
    paper view and is unchanged.
    """
    if attn_impl is not None:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if schedule is not None and not training:
        raise ValueError(
            "schedule-aware accounting models training residency; for "
            "inference sizing of a multi-chunk rank pass the rank's layer "
            "list via device_params(layers=...) instead")
    if schedule is not None and in_flight_microbatches is not None:
        raise ValueError(
            "in_flight_microbatches conflicts with schedule=: the schedule "
            "path derives residency from its own tick stream — cap it with "
            "n_micro= instead")
    if schedule is not None:
        from .activations import rank_chunk_layers, schedule_activation_bytes
        rank = stage if stage is not None else 0
        chunks = rank_chunk_layers(spec, cfg.pp, schedule=schedule,
                                   n_chunks=n_chunks)[rank]
        layers = [l for ls in chunks for l in ls]
        state = zero_memory(spec, cfg, layers=layers)
        params, grads, opt = state.params, state.grads, state.optimizer
        acts = schedule_activation_bytes(spec, cfg, rank, schedule=schedule,
                                         n_chunks=n_chunks, n_micro=n_micro)
        zp = cfg.zero == ZeROStage.OS_G_PARAMS
        if schedule == "zb1p":
            # The B→W stash: one fp32 pending-dW copy of the rank's
            # per-layer grads per pending microbatch, parked in the
            # executor's scan-carried stash ring from B until the deferred
            # W flushes it (see train.schedules — the stash colouring
            # windows run B→W, so the ring depth IS the peak pendency).
            # SPMD allocates the ring uniformly, so every rank pays the
            # schedule-wide max; shared (embed/head/final-norm) grads
            # accumulate at B and never enter the stash.
            from .schedules import zb_pending_peak
            m_eff = n_micro if n_micro is not None else 2 * cfg.pp
            pend = max(zb_pending_peak(cfg.pp, m_eff))
            dev = device_params(spec, cfg, layers=layers)
            if zp:
                # ZeRO-3: the stash is zeros_like the DP-sharded layer
                # leaves — gather_params' backward hands B a shard-sized,
                # already-reduced dW, so the ring shrinks with the params
                stash_p = (-(-(dev.non_expert - dev.embed) // cfg.dp)
                           + -(-dev.expert // cfg.edp))
            else:
                stash_p = dev.total - dev.embed
            grads += pend * stash_p * 4
        gather = 0
        if zp and (cfg.dp > 1 or cfg.edp > 1):
            # Gather-on-use working copy: the executor all-gathers one
            # chunk's full bf16 params per F/B tick; the copy is live
            # from the gather to the end of that chunk's grad retirement,
            # so at peak one full (largest) chunk rides on top of the
            # sharded residency — same transient-at-peak treatment as
            # the zb1p pending-dW ring above.
            gather = max(device_params(spec, cfg, layers=ls).total
                         for ls in chunks) * cfg.dtype.weights
        subtotal = (params + grads + opt + acts + cfg.comm_buffer_bytes
                    + gather)
        frag = int(subtotal * cfg.fragmentation)
        return MemoryEstimate(params=params, grads=grads, optimizer=opt,
                              activations=acts,
                              comm_buffers=cfg.comm_buffer_bytes,
                              fragmentation=frag,
                              gather_transient=gather)
    state = zero_memory(spec, cfg, stage=stage)
    if not training:
        dev = device_params(spec, cfg, stage=stage)
        params = dev.total * cfg.dtype.weights
        acts = kv_cache_bytes(spec, cfg)
        grads = opt = 0
    else:
        params, grads, opt = state.params, state.grads, state.optimizer
        acts = stage_activation_bytes(spec, cfg, stage=stage,
                                      in_flight=in_flight_microbatches)
    subtotal = params + grads + opt + acts + cfg.comm_buffer_bytes
    frag = int(subtotal * cfg.fragmentation)
    return MemoryEstimate(params=params, grads=grads, optimizer=opt,
                          activations=acts, comm_buffers=cfg.comm_buffer_bytes,
                          fragmentation=frag)


def kv_cache_bytes(spec: ModelSpec, cfg: ParallelConfig,
                   batch: Optional[int] = None,
                   seq: Optional[int] = None) -> int:
    """Decode-time cache per device: MLA caches the compressed latent
    (d_c + d_hr per token — the MLA inference advantage), GQA caches
    2·n_kv·d_head, SSM keeps O(1) state, sliding-window caps s at the window."""
    from .notation import AttentionKind
    b = batch if batch is not None else cfg.micro_batch
    s = seq if seq is not None else cfg.seq_len
    act = cfg.dtype.activation
    n_layers_local = max(1, spec.n_layers // cfg.pp)
    per_tok = 0
    if spec.attention == AttentionKind.MLA:
        per_tok = spec.mla.d_c + spec.mla.d_hr
    elif spec.attention != AttentionKind.NONE:
        kv_shard = min(cfg.tp, spec.n_kv)
        per_tok = 2 * spec.n_kv * spec.d_head // kv_shard
    s_eff = min(s, spec.sliding_window) if spec.sliding_window else s
    cache = b * s_eff * per_tok * act * n_layers_local
    if spec.ssm is not None:
        ss = spec.ssm
        d = spec.h * ss.ssm_expand
        head_dim = d // max(ss.n_ssm_heads, 1)
        cache += (b * ss.n_ssm_heads * head_dim * ss.state_dim * act
                  * n_layers_local)
    return cache


def fits(spec: ModelSpec, cfg: ParallelConfig, hbm_bytes: int, **kw) -> bool:
    return estimate_memory(spec, cfg, **kw).total <= hbm_bytes
