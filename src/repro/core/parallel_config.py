"""Distributed-training configuration (paper Table 5) and validation.

The paper's notation:
  DP  data parallelism (non-expert params' gradient-sync group)
  TP  tensor parallelism (Megatron column/row split of attention & dense MLP)
  PP  pipeline parallelism (layer stages)
  EP  expert parallelism (routed experts distributed across ranks)
  ETP expert tensor parallelism (TP inside an expert)
  EDP expert data parallelism (derived: world / (PP*EP*ETP))
  SP  sequence parallelism (Megatron-style, tied to TP degree)
  CP  context parallelism
World size = DP * TP * PP, and DP * TP = EDP * EP * ETP must hold so the
expert and non-expert groups tile the same set of devices.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class ZeROStage(enum.Enum):
    NONE = "none"
    OS = "os"                    # shard optimizer states over DP/EDP
    OS_G = "os+g"                # + gradients
    OS_G_PARAMS = "os+g+params"  # + parameters (ZeRO-3)


class RecomputePolicy(enum.Enum):
    NONE = "none"          # store all intermediate activations
    FULL = "full"          # store only per-block inputs (paper: 2bsh/SP per norm pair)
    SELECTIVE = "selective"  # store all but attention-score/softmax & expert ffn internals


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Bytes per parameter/value (paper Table 7)."""

    weights: int = 2          # BF16
    activation: int = 2       # BF16
    gradient: int = 4         # FP32
    opt_master: int = 4       # FP32 copy of params
    opt_momentum: int = 2     # BF16
    opt_variance: int = 2     # BF16

    @property
    def optimizer(self) -> int:
        return self.opt_master + self.opt_momentum + self.opt_variance


BF16_POLICY = DTypePolicy()
# Beyond-paper extension: FP8 weights with BF16 master-ish accumulation.
FP8_POLICY = DTypePolicy(weights=1, activation=1, gradient=4,
                         opt_master=4, opt_momentum=2, opt_variance=2)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1
    etp: int = 1
    cp: int = 1
    sp: bool = False                     # Megatron SP: degree == tp when on
    zero: ZeROStage = ZeROStage.NONE
    recompute: RecomputePolicy = RecomputePolicy.NONE
    # paper §5: "how many layers to recompute, which specific layers" —
    # fraction of each stage's layers the recompute policy applies to;
    # the rest store activations as AC-None.
    recompute_fraction: float = 1.0
    micro_batch: int = 1
    seq_len: int = 4096
    dtype: DTypePolicy = BF16_POLICY
    # Attention score-path implementation the executor runs:
    #   "naive"   — materialises the (b, n_h, s, s) score/softmax/mask
    #               buffers (the paper's 5·b·n_h·s² term);
    #   "flash" / "pallas" — tiled online-softmax kernel: the s² buffers
    #               exist only transiently inside one layer's fwd/bwd and
    #               never join the resident activation stash;
    #   "chunked" — jnp lax.scan online-softmax: O(s) live memory in the
    #               forward, but its scan carries still stash O(s²)
    #               residuals under AD, so it does NOT get the flash
    #               discount in the memory model.
    attn_impl: str = "naive"
    # §6: temporary comm buffers [0.8, 2] GB and fragmentation [5%, 30%].
    comm_buffer_bytes: int = int(0.8 * 2**30)
    fragmentation: float = 0.05

    def __post_init__(self) -> None:
        for name in ("dp", "tp", "pp", "ep", "etp", "cp", "micro_batch", "seq_len"):
            v = getattr(self, name)
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")
        if (self.dp * self.tp) % (self.ep * self.etp) != 0:
            raise ValueError(
                f"DP*TP ({self.dp}*{self.tp}) must be divisible by EP*ETP "
                f"({self.ep}*{self.etp}) so expert groups tile the device grid")

    @property
    def world_size(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def edp(self) -> int:
        """Expert data parallelism (paper: EDP = DP*TP / (EP*ETP))."""
        return (self.dp * self.tp) // (self.ep * self.etp)

    @property
    def sp_degree(self) -> int:
        return self.tp if self.sp else 1

    def describe(self) -> str:
        attn = "" if self.attn_impl == "naive" else f" attn={self.attn_impl}"
        return (f"DP{self.dp}@TP{self.tp}@PP{self.pp}@EP{self.ep}@ETP{self.etp}"
                f"@EDP{self.edp}@CP{self.cp}@SP{self.sp_degree}"
                f" zero={self.zero.value} ac={self.recompute.value}"
                f" b={self.micro_batch} s={self.seq_len}{attn}")


# Paper Table 5 reference case.
PAPER_CONFIG = ParallelConfig(dp=32, tp=2, pp=16, ep=8, etp=1, sp=True,
                              micro_batch=1, seq_len=4096)
