"""Beyond-paper: parallel-configuration planner.

The paper derives memory for ONE hand-picked config (Table 5).  The natural
product of its analysis is a *search*: given a model, a device HBM budget and
a world size, enumerate feasible (TP, PP, EP, ZeRO, recompute, micro-batch)
configurations and rank them — fewest-recompute-first (recompute trades ~30%
step FLOPs for memory), then widest micro-batch, then least model-parallel
fragmentation.

Public entry points:

* ``enumerate_configs(spec, world_size, *, seq_len, micro_batches, max_tp,
  zero_stages, recompute, sp)`` — every coherent ``ParallelConfig`` tiling
  ``world_size`` devices (PP ≤ n_layers, TP | n_heads, EP | n_experts).
* ``plan(spec, world_size, hbm_bytes, *, seq_len, top_k, pp_in_flight,
  schedule, n_chunks)`` — feasible configs under the HBM budget,
  best-first, each as a ``PlanEntry`` carrying its ``MemoryEstimate``,
  ``headroom`` against the budget, and a ``runnable`` flag — True exactly
  when the 3D pipeline executor (``train.pipeline_loop``) can run the
  config end to end; estimator/dry-run-only configs carry
  ``why_not_runnable``.  ``pp_in_flight`` prices pp>1 configs
  at the pipeline schedule's steady-state residency (default plain 1F1B;
  ``schedule='interleaved'|'dualpipe'`` uses the schedule-aware
  ``estimate_memory`` — see ``docs/pipeline-schedules.md``).
* ``min_memory_config(spec, world_size)`` — the single lightest config,
  budget-free.

The planner writes no artifacts; ``benchmarks/run.py`` and
``examples/memory_planner.py`` print its tables.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from .activations import one_f1b_in_flight
from .memory_model import MemoryEstimate, estimate_memory
from .notation import AttentionKind, FamilyKind, ModelSpec, tp_violations
from .parallel_config import ParallelConfig, RecomputePolicy, ZeROStage
from .steptime import predict_step_time


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    cfg: ParallelConfig
    estimate: MemoryEstimate
    budget: Optional[int] = None    # HBM bytes the plan was ranked against
    # Whether train.pipeline_loop's 3D executor can actually run this config
    # end to end (vs. estimator/dry-run-only); see executor_runnable().
    runnable: bool = True
    why_not_runnable: str = ""
    # Executor-model step time (core.steptime.predict_step_time) under the
    # plan's schedule — the quantity runnable configs are ranked by.  None
    # when prediction is unavailable (e.g. schedule/pp mismatch).
    predicted_step_s: Optional[float] = None

    @property
    def headroom(self) -> int:
        return self.budget - self.estimate.total if self.budget else 0


def executor_runnable(spec: ModelSpec, cfg: ParallelConfig, *,
                      schedule: str = "1f1b") -> Tuple[bool, str]:
    """Can ``train.pipeline_loop.make_pipeline_train_step`` execute this
    config?  (False, reason) for estimator/dry-run-only configurations.

    The executor runs dense/MoE decoder-only families on
    ('pipe','data','model') meshes with manual TP (exact divisibility
    required), Megatron-style sequence parallelism (degree tied to tp —
    ``make_pipeline_train_step(..., sp=True)``; the seq-sharded boundary
    requires ``seq_len % tp == 0``), the full ZeRO ladder — os / os+g via
    sharding constraints and os+g+params (ZeRO-3) via gather-on-use
    parameter partitioning (``parallel.tp.gather_params``) — and MoE
    either ETP-style (ep=1: all experts on every shard, expert-ff sharded)
    or true expert-parallel (``make_pipeline_train_step(..., ep=tp)``:
    expert-dim weight shards + a2a token dispatch over 'model') — so
    grouped EP off the 'model' axis (1 < ep < tp or ep ∤ devices), context
    parallelism and the recurrent / enc-dec / VLM families remain analytic
    or GSPMD-dry-run territory."""
    if spec.ssm is not None:
        return False, "SSM/hybrid family (pipeline runtime unsupported)"
    if spec.encoder is not None:
        return False, "enc-dec family (pipeline runtime unsupported)"
    if spec.family == FamilyKind.VLM:
        return False, "VLM frontend (pipeline runtime unsupported)"
    if spec.attention == AttentionKind.NONE:
        return False, "attention-free family (pipeline runtime unsupported)"
    bad = tp_violations(spec, cfg.tp, sp=cfg.sp_degree, seq_len=cfg.seq_len,
                        ep=cfg.ep, attn_impl=cfg.attn_impl)
    if bad:
        return False, f"indivisible parallel degrees: {', '.join(bad)}"
    if cfg.cp > 1:
        return False, "context parallelism not executed"
    if spec.is_moe and cfg.ep > 1:
        # executor EP: a2a dispatch group == the whole 'model' axis, so
        # only ep == tp runs; the wider enumeration space (any ep dividing
        # dp*tp) stays estimator-only with the reason recorded here
        if cfg.ep != cfg.tp:
            return False, (f"executor EP ties the a2a dispatch group to the "
                           f"'model' axis (ep == tp); ep={cfg.ep} with "
                           f"tp={cfg.tp} is estimator-only")
        if (cfg.micro_batch * cfg.seq_len) % cfg.ep:
            return False, (f"ep={cfg.ep} does not divide the per-rank token "
                           f"count {cfg.micro_batch * cfg.seq_len}")
    if cfg.etp not in (1, cfg.tp):
        return False, f"executor ties ETP to TP (etp={cfg.etp}, tp={cfg.tp})"
    if schedule == "dualpipe" and cfg.pp < 2:
        return False, "dualpipe needs pp >= 2"
    # schedule constraints on the microbatch *count* (e.g. interleaved's
    # n_micro % pp == 0) are runtime arguments, not ParallelConfig fields —
    # they surface when the step is built, not here
    return True, ""


def _divisors(n: int, cap: int = 1 << 30) -> List[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def enumerate_configs(spec: ModelSpec, world_size: int, *,
                      seq_len: int,
                      micro_batches: Sequence[int] = (1, 2, 4),
                      max_tp: int = 16,
                      zero_stages: Sequence[ZeROStage] = tuple(ZeROStage),
                      recompute: Sequence[RecomputePolicy] = (
                          RecomputePolicy.NONE, RecomputePolicy.SELECTIVE,
                          RecomputePolicy.FULL),
                      sp: bool = True) -> Iterable[ParallelConfig]:
    """All coherent configs tiling ``world_size`` devices."""
    n_exp = spec.moe.n_routed if spec.is_moe else 1
    for pp in _divisors(world_size):
        if pp > spec.n_layers:
            continue
        rest = world_size // pp
        for tp in _divisors(rest, cap=max_tp):
            if spec.n_h % tp:
                continue
            dp = rest // tp
            eps = [e for e in _divisors(dp * tp) if n_exp % e == 0] \
                if spec.is_moe else [1]
            for ep in eps:
                if (dp * tp) % ep:
                    continue
                for z, r, b in itertools.product(zero_stages, recompute,
                                                 micro_batches):
                    try:
                        yield ParallelConfig(
                            dp=dp, tp=tp, pp=pp, ep=ep, etp=1, sp=sp and tp > 1,
                            zero=z, recompute=r, micro_batch=b, seq_len=seq_len)
                    except ValueError:
                        continue


def plan(spec: ModelSpec, world_size: int, hbm_bytes: int, *,
         seq_len: int = 4096, top_k: int = 10, pp_in_flight: bool = True,
         schedule: str = "1f1b", n_chunks: int = 1,
         n_micro: Optional[int] = None,
         **enum_kw) -> List[PlanEntry]:
    """Feasible configs under the HBM budget, best-first.

    Ranking: *runnable* configs first, ordered by the executor-model step
    time (``core.steptime.predict_step_time`` under ``schedule`` with
    ``n_micro`` microbatches — default ``2·pp``, enough for every schedule
    to reach steady state) with the legacy memory ordering as tie-break;
    estimator-only configs follow under the legacy ordering alone: least
    recompute, largest micro-batch, least TP*PP (model-parallel keeps
    devices busier when avoidable), then least total memory.

    ``pp_in_flight`` sizes activations for the pipeline schedule's steady
    state (the runtime's behaviour): under the default ``schedule='1f1b'``
    the worst stage holds ``one_f1b_in_flight(pp, 0)`` = pp microbatches,
    not 1 — without it the planner admits pp>1 configs the executor would
    OOM.  Set False for the paper's single-microbatch view.

    ``schedule`` ∈ {1f1b, interleaved, dualpipe} ranks against that
    schedule's worst rank via the schedule-aware ``estimate_memory``,
    maxing over *all* ranks — rank 0 is not reliably the heaviest: under
    dualpipe an interior rank can hold a larger stage pair, and under
    interleaved a back rank's chunks can carry the parameter-heavy (MoE)
    layers.  Interleaved (with ``n_chunks`` virtual stages) raises the
    in-flight ceiling to ``(v-1)·pp + 2pp - 1`` chunk units; dualpipe
    doubles parameter state and flattens activations to ~pp+1.  The
    default keeps the legacy 1F1B ranking bit-for-bit.
    """
    if schedule != "1f1b":
        from .schedules import norm_chunks
        norm_chunks(schedule, n_chunks)   # reject bad schedule/n_chunks now,
        # so the per-config skip below only ever hides configs that are
        # genuinely infeasible (pp * n_chunks > n_layers), not typos
    order_r = {RecomputePolicy.NONE: 0, RecomputePolicy.SELECTIVE: 1,
               RecomputePolicy.FULL: 2}
    entries: List[PlanEntry] = []
    for cfg in enumerate_configs(spec, world_size, seq_len=seq_len, **enum_kw):
        if pp_in_flight and schedule != "1f1b" and cfg.pp > 1:
            try:
                est = max((estimate_memory(spec, cfg, stage=r,
                                           schedule=schedule,
                                           n_chunks=n_chunks)
                           for r in range(cfg.pp)), key=lambda e: e.total)
            except ValueError:      # pp * n_chunks > n_layers (or dualpipe pp=1)
                continue
        else:
            in_flight = one_f1b_in_flight(cfg.pp, 0) if pp_in_flight else None
            est = estimate_memory(spec, cfg, in_flight_microbatches=in_flight)
        if est.total <= hbm_bytes:
            ok, why = executor_runnable(spec, cfg, schedule=schedule)
            pred = None
            if ok:
                try:
                    m = n_micro if n_micro is not None else max(2 * cfg.pp,
                                                                n_chunks)
                    if schedule == "interleaved" and m % cfg.pp:
                        m = ((m + cfg.pp - 1) // cfg.pp) * cfg.pp
                    pred = predict_step_time(
                        spec, schedule, cfg.pp, m,
                        micro_batch=cfg.micro_batch, seq_len=cfg.seq_len,
                        n_chunks=n_chunks, tp=cfg.tp,
                        sp=cfg.sp_degree > 1,
                        zero=cfg.zero, dp=cfg.dp).total_s
                except ValueError:
                    pred = None
            entries.append(PlanEntry(cfg, est, budget=hbm_bytes,
                                     runnable=ok, why_not_runnable=why,
                                     predicted_step_s=pred))

    def legacy(e: PlanEntry):
        return (order_r[e.cfg.recompute], -e.cfg.micro_batch,
                e.cfg.tp * e.cfg.pp, e.estimate.total)

    entries.sort(key=lambda e: (
        (0, e.predicted_step_s) + legacy(e)
        if e.runnable and e.predicted_step_s is not None
        else (1,) + legacy(e) + (0,)))
    return entries[:top_k]


def min_memory_config(spec: ModelSpec, world_size: int, *,
                      seq_len: int = 4096, **enum_kw) -> Optional[PlanEntry]:
    best: Optional[PlanEntry] = None
    for cfg in enumerate_configs(spec, world_size, seq_len=seq_len, **enum_kw):
        est = estimate_memory(spec, cfg)
        if best is None or est.total < best.estimate.total:
            best = PlanEntry(cfg, est)
    return best
