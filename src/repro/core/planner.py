"""Beyond-paper: parallel-configuration planner.

The paper derives memory for ONE hand-picked config (Table 5).  The natural
product of its analysis is a *search*: given a model, a device HBM budget and
a world size, enumerate feasible (TP, PP, EP, ZeRO, recompute, micro-batch)
configurations and rank them — fewest-recompute-first (recompute trades ~30%
step FLOPs for memory), then widest micro-batch, then least model-parallel
fragmentation.

Public entry points:

* ``enumerate_configs(spec, world_size, *, seq_len, micro_batches, max_tp,
  zero_stages, recompute, sp)`` — every coherent ``ParallelConfig`` tiling
  ``world_size`` devices (PP ≤ n_layers, TP | n_heads, EP | n_experts).
* ``plan(spec, world_size, hbm_bytes, *, seq_len, top_k, pp_in_flight,
  schedule, n_chunks)`` — feasible configs under the HBM budget,
  best-first, each as a ``PlanEntry`` carrying its ``MemoryEstimate`` and
  ``headroom`` against the budget.  ``pp_in_flight`` prices pp>1 configs
  at the pipeline schedule's steady-state residency (default plain 1F1B;
  ``schedule='interleaved'|'dualpipe'`` uses the schedule-aware
  ``estimate_memory`` — see ``docs/pipeline-schedules.md``).
* ``min_memory_config(spec, world_size)`` — the single lightest config,
  budget-free.

The planner writes no artifacts; ``benchmarks/run.py`` and
``examples/memory_planner.py`` print its tables.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from .activations import one_f1b_in_flight
from .memory_model import MemoryEstimate, estimate_memory
from .notation import ModelSpec
from .parallel_config import ParallelConfig, RecomputePolicy, ZeROStage


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    cfg: ParallelConfig
    estimate: MemoryEstimate
    budget: Optional[int] = None    # HBM bytes the plan was ranked against

    @property
    def headroom(self) -> int:
        return self.budget - self.estimate.total if self.budget else 0


def _divisors(n: int, cap: int = 1 << 30) -> List[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def enumerate_configs(spec: ModelSpec, world_size: int, *,
                      seq_len: int,
                      micro_batches: Sequence[int] = (1, 2, 4),
                      max_tp: int = 16,
                      zero_stages: Sequence[ZeROStage] = tuple(ZeROStage),
                      recompute: Sequence[RecomputePolicy] = (
                          RecomputePolicy.NONE, RecomputePolicy.SELECTIVE,
                          RecomputePolicy.FULL),
                      sp: bool = True) -> Iterable[ParallelConfig]:
    """All coherent configs tiling ``world_size`` devices."""
    n_exp = spec.moe.n_routed if spec.is_moe else 1
    for pp in _divisors(world_size):
        if pp > spec.n_layers:
            continue
        rest = world_size // pp
        for tp in _divisors(rest, cap=max_tp):
            if spec.n_h % tp:
                continue
            dp = rest // tp
            eps = [e for e in _divisors(dp * tp) if n_exp % e == 0] \
                if spec.is_moe else [1]
            for ep in eps:
                if (dp * tp) % ep:
                    continue
                for z, r, b in itertools.product(zero_stages, recompute,
                                                 micro_batches):
                    try:
                        yield ParallelConfig(
                            dp=dp, tp=tp, pp=pp, ep=ep, etp=1, sp=sp and tp > 1,
                            zero=z, recompute=r, micro_batch=b, seq_len=seq_len)
                    except ValueError:
                        continue


def plan(spec: ModelSpec, world_size: int, hbm_bytes: int, *,
         seq_len: int = 4096, top_k: int = 10, pp_in_flight: bool = True,
         schedule: str = "1f1b", n_chunks: int = 1,
         **enum_kw) -> List[PlanEntry]:
    """Feasible configs under the HBM budget, best-first.

    Ranking: least recompute, largest micro-batch, least TP*PP (model-parallel
    keeps devices busier when avoidable), then most headroom.

    ``pp_in_flight`` sizes activations for the pipeline schedule's steady
    state (the runtime's behaviour): under the default ``schedule='1f1b'``
    the worst stage holds ``one_f1b_in_flight(pp, 0)`` = pp microbatches,
    not 1 — without it the planner admits pp>1 configs the executor would
    OOM.  Set False for the paper's single-microbatch view.

    ``schedule`` ∈ {1f1b, interleaved, dualpipe} ranks against that
    schedule's worst rank via the schedule-aware ``estimate_memory``,
    maxing over *all* ranks — rank 0 is not reliably the heaviest: under
    dualpipe an interior rank can hold a larger stage pair, and under
    interleaved a back rank's chunks can carry the parameter-heavy (MoE)
    layers.  Interleaved (with ``n_chunks`` virtual stages) raises the
    in-flight ceiling to ``(v-1)·pp + 2pp - 1`` chunk units; dualpipe
    doubles parameter state and flattens activations to ~pp+1.  The
    default keeps the legacy 1F1B ranking bit-for-bit.
    """
    if schedule != "1f1b":
        from .schedules import norm_chunks
        norm_chunks(schedule, n_chunks)   # reject bad schedule/n_chunks now,
        # so the per-config skip below only ever hides configs that are
        # genuinely infeasible (pp * n_chunks > n_layers), not typos
    order_r = {RecomputePolicy.NONE: 0, RecomputePolicy.SELECTIVE: 1,
               RecomputePolicy.FULL: 2}
    entries: List[PlanEntry] = []
    for cfg in enumerate_configs(spec, world_size, seq_len=seq_len, **enum_kw):
        if pp_in_flight and schedule != "1f1b" and cfg.pp > 1:
            try:
                est = max((estimate_memory(spec, cfg, stage=r,
                                           schedule=schedule,
                                           n_chunks=n_chunks)
                           for r in range(cfg.pp)), key=lambda e: e.total)
            except ValueError:      # pp * n_chunks > n_layers (or dualpipe pp=1)
                continue
        else:
            in_flight = one_f1b_in_flight(cfg.pp, 0) if pp_in_flight else None
            est = estimate_memory(spec, cfg, in_flight_microbatches=in_flight)
        if est.total <= hbm_bytes:
            entries.append(PlanEntry(cfg, est, budget=hbm_bytes))
    entries.sort(key=lambda e: (order_r[e.cfg.recompute], -e.cfg.micro_batch,
                                e.cfg.tp * e.cfg.pp, e.estimate.total))
    return entries[:top_k]


def min_memory_config(spec: ModelSpec, world_size: int, *,
                      seq_len: int = 4096, **enum_kw) -> Optional[PlanEntry]:
    best: Optional[PlanEntry] = None
    for cfg in enumerate_configs(spec, world_size, seq_len=seq_len, **enum_kw):
        est = estimate_memory(spec, cfg)
        if best is None or est.total < best.estimate.total:
            best = PlanEntry(cfg, est)
    return best
