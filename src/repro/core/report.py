"""Render the paper's tables from the analytical model (used by benchmarks)."""

from __future__ import annotations

import dataclasses
from typing import List

from . import params as P
from .activations import table10
from .memory_model import estimate_memory
from .notation import ModelSpec, human_bytes, human_count
from .parallel_config import ParallelConfig, RecomputePolicy, ZeROStage
from .zero import zero_table


def _table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) for i, h in enumerate(headers)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*headers), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*r) for r in rows]
    return "\n".join(lines)


def render_table3(spec: ModelSpec) -> str:
    rows = []
    for r in P.table3_rows(spec):
        for i, (mod, n) in enumerate(r.modules.items()):
            rows.append([r.layers if i == 0 else "", mod, f"{n:,}",
                         human_count(r.per_layer) if i == 0 else "",
                         human_bytes(r.per_layer * 2) if i == 0 else ""])
    total = P.total_params_paper(spec)
    rows.append(["Total", "", f"{total:,}", human_count(total),
                 human_bytes(total * 2)])
    return _table(["Layers", "Module", "No. Params", "Per Layer", "BF16"], rows)


def render_table4(spec: ModelSpec, pp: int) -> str:
    rows = []
    for r in P.table4_stages(spec, pp):
        rows.append([f"Stage {r.stage}", str(len(r.layers)),
                     human_count(r.params), human_bytes(r.params * 2)])
    total = sum(r.params for r in P.table4_stages(spec, pp))
    rows.append(["Sum", str(spec.n_layers), human_count(total),
                 human_bytes(total * 2)])
    return _table(["Stage", "Layers", "Params", "BF16"], rows)


def render_table6(spec: ModelSpec, cfg: ParallelConfig) -> str:
    d = P.device_params(spec, cfg)
    rows = [
        ["RMSNorm 1&2", f"{d.norms:,}", human_bytes(d.norms * 2)],
        ["Attn (TP split)", f"{d.attn_tp:,}", human_bytes(d.attn_tp * 2)],
        ["Attn (replicated)", f"{d.attn_replicated:,}",
         human_bytes(d.attn_replicated * 2)],
        ["Dense MLP", f"{d.dense_mlp:,}", human_bytes(d.dense_mlp * 2)],
        ["SSM", f"{d.ssm:,}", human_bytes(d.ssm * 2)],
        ["Embed/Head", f"{d.embed:,}", human_bytes(d.embed * 2)],
        ["Non-MoE part", f"{d.non_expert:,}", human_bytes(d.non_expert * 2)],
        ["Router", f"{d.router:,}", human_bytes(d.router * 2)],
        ["Experts", f"{d.experts:,}", human_bytes(d.experts * 2)],
        ["MoE part", f"{d.expert:,}", human_bytes(d.expert * 2)],
        ["Total", f"{d.total:,}", human_bytes(d.total * 2)],
    ]
    return _table(["Module", "Params/device", "Bytes"], rows)


def render_table8(spec: ModelSpec, cfg: ParallelConfig) -> str:
    rows = []
    for name, m in zero_table(spec, cfg).items():
        rows.append([name, human_bytes(m.params), human_bytes(m.grads),
                     human_bytes(m.optimizer), human_bytes(m.total)])
    return _table(["ZeRO", "Params", "Grads", "Optimizer", "P+G+O"], rows)


def render_table10(spec: ModelSpec, cfg: ParallelConfig) -> str:
    t = table10(spec, cfg)
    rows = []
    for comp in ("MLA", "MoE", "Total"):
        rows.append([comp, human_bytes(t["none"][comp]),
                     human_bytes(t["full"][comp])])
    return _table([f"Component (b={cfg.micro_batch}, s={cfg.seq_len})",
                   "AC None", "AC Full"], rows)


def render_full_estimate(spec: ModelSpec, cfg: ParallelConfig) -> str:
    rows = []
    for z in ZeROStage:
        for r in (RecomputePolicy.NONE, RecomputePolicy.FULL):
            c = dataclasses.replace(cfg, zero=z, recompute=r)
            e = estimate_memory(spec, c)
            rows.append([z.value, r.value, human_bytes(e.state_total),
                         human_bytes(e.activations), human_bytes(e.total)])
    return _table(["ZeRO", "AC", "P+G+O", "Activations", "Total/device"], rows)
