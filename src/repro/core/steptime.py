"""Analytic step-time / bubble model (the perf-trajectory layer).

The memory model answers "does it fit"; this module answers "how long is a
step" — at the same level of abstraction and from the same primitive, the
schedule tick stream of :mod:`core.schedules`.  Two views are provided, and
it matters which one a caller wants:

**Ideal timeline** (:func:`bubble_stats`): re-time the canonical per-rank op
order with real op durations — forward ``t_f``, input-gradient backward
``t_b``, weight-gradient ``t_w`` (schedules that do not split the backward
run B as one op of duration ``t_b + t_w``) — and report the makespan and
the bubble fraction ``1 - busy / (pp * makespan)``.  This is the number the
schedule literature quotes: with ``t_f = t_b = t_w``, 1f1b's bubble is
``2(pp-1)`` op-slots per rank and zb1p's collapses toward ``(pp-1)``
(ZB-H1's ``(p-1)(F+B-W)``, arXiv:2401.10241), which is *why* zero-bubble
schedules exist.

**Executor model** (:func:`predict_step_time`): what
``train.pipeline_loop``'s SPMD executor will actually measure.  Two
executor views, selected by ``view=``:

* ``"overlapped"`` (the default — the overlap engine): each tick costs
  only the work its cond-gated branches actually run, so per tick the
  model takes the *slowest rank's* active compute and overlaps the
  boundary-ring traffic against it — wall clock
  ``Σ_t max(max_r compute(t, r), comm) + T × overhead``.  Per-activity
  compute weights (in chunk-forward units, :func:`exec_tick_activity`):
  F = 1; the fused recompute backward (1f1b/interleaved/dualpipe, slot
  checkpointing on) = 4 (replay + dx + dW); zb1p's B runs the full vjp
  *without* slot checkpointing (no replay — it stashes the fp32
  pending-dW instead of recomputing activations) = 3, and its W is a
  pure stash→accumulator flush ≈ 0.25.  That asymmetry — 1f1b pays the
  recompute inside every fused backward while zb1p skips it entirely at
  the price of the grad stash — is exactly the zero-bubble trade, and
  it is why zb1p's measured step can now dip *below* 1f1b's despite its
  longer tick table.  (On a serializing CPU host the saving holds only
  while the chunk's saved intermediates fit the core's cache — the
  ``cache_bytes`` cliff in :func:`predict_step_time`.)
* ``"masked"`` (the legacy pre-overlap executor): one full masked chunk
  forward + one full masked chunk vjp per rank per tick regardless of
  activity — wall clock ``T_exec × per-tick cost``.  Kept as the
  reference cost model the overlap engine is measured against
  (``docs/perf-trajectory.md`` tracks the measured/ideal convergence).

The benchmark harness (``benchmarks/step_bench.py``) gates measured
rankings against the overlapped view, not the ideal one.

Also here: the analytic FLOPs the harness converts wall clock into MFU with
(:func:`model_fwd_flops` / :func:`step_flops` / :func:`mfu`), counting
dense-matmul + attention-score work per token, PaLM-appendix style.

Pure Python/numpy — ``core`` stays jax-free.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Tuple

from .notation import AttentionKind, ModelSpec
from .schedules import (PipelineSchedule, exec_tick_times, make_schedule,
                        n_model_chunks, norm_chunks)

# Nominal device constants for the executor model.  Rankings across
# schedules — the only thing CI asserts — are insensitive to them; the
# benchmark harness substitutes host-calibrated values for absolute
# predictions.  Defaults: A100-class bf16 peak and NVLink-class bandwidth.
NOMINAL_FLOPS_PER_S = 312e12
NOMINAL_BYTES_PER_S = 300e9


# ---------------------------------------------------------------------------
# Analytic FLOPs (MFU's denominator)
# ---------------------------------------------------------------------------

def layer_fwd_flops(spec: ModelSpec, layer_idx: int, tokens: int,
                    seq_len: int) -> float:
    """Forward FLOPs of transformer layer ``layer_idx`` for ``tokens``
    tokens at context ``seq_len``: 2 FLOPs per parameter per token for the
    projections (MoE layers count only the *active* experts + router), plus
    the attention score/value quadratic ``4·tokens·s·n_h·d`` (QKᵀ and A·V,
    causal masking not discounted — the kernels compute the full product).
    Norm/elementwise work is omitted (sub-percent)."""
    proj = spec.attn_params_per_layer(include_qk_norm=False)
    if spec.is_moe and layer_idx in spec.moe_layer_indices():
        proj += spec.moe_active_params_per_layer()
    elif spec.h_ff:
        proj += spec.dense_mlp_params_per_layer()
    if spec.ssm is not None:
        proj += spec.ssm_params_per_layer()
    flops = 2.0 * tokens * proj
    if spec.attention == AttentionKind.MLA:
        d_eff = spec.mla.d_h + spec.mla.d_hr
        flops += 4.0 * tokens * seq_len * spec.n_h * d_eff
    elif spec.attention != AttentionKind.NONE:
        flops += 4.0 * tokens * seq_len * spec.n_h * spec.d_head
    return flops


def model_fwd_flops(spec: ModelSpec, tokens: int, seq_len: int) -> float:
    """Forward FLOPs of the full model: all layers + the vocab head
    (``2·tokens·h·v``; the embedding lookup is free)."""
    flops = sum(layer_fwd_flops(spec, l, tokens, seq_len)
                for l in range(spec.n_layers))
    return flops + 2.0 * tokens * spec.h * spec.vocab


def step_flops(spec: ModelSpec, tokens: int, seq_len: int, *,
               recompute: bool = False) -> float:
    """Model FLOPs of one training step over ``tokens`` tokens: forward +
    2× forward for the backward (the PaLM-appendix 3× convention).  MFU
    deliberately excludes rematerialization — pass ``recompute=True`` only
    to price *hardware* FLOPs (e.g. the executor's chunk-recompute
    backward, a 4× multiplier)."""
    mult = 4.0 if recompute else 3.0
    return mult * model_fwd_flops(spec, tokens, seq_len)


def mfu(step_time_s: float, spec: ModelSpec, tokens: int, seq_len: int, *,
        peak_flops_per_s: float, n_devices: int = 1) -> float:
    """Model-FLOPs utilization: analytic step FLOPs (no recompute credit)
    over the hardware's peak across ``n_devices`` for ``step_time_s``."""
    if step_time_s <= 0 or peak_flops_per_s <= 0 or n_devices < 1:
        raise ValueError("mfu needs positive time, peak and device count")
    return step_flops(spec, tokens, seq_len) / (
        step_time_s * peak_flops_per_s * n_devices)


# ---------------------------------------------------------------------------
# Ideal timeline: weighted retiming of the canonical op order
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BubbleStats:
    """Weighted-retiming summary of one schedule's canonical timeline."""

    schedule: str
    pp: int
    n_micro: int
    n_chunks: int
    makespan: float                 # critical-path length, op-duration units
    busy: Tuple[float, ...]         # per-rank total op time
    bubble_fraction: float          # 1 - sum(busy) / (pp * makespan)


def weighted_finish_times(sched: PipelineSchedule, *, t_f: float = 1.0,
                          t_b: float = 1.0, t_w: float = 1.0
                          ) -> Dict[Tuple[str, int, int], float]:
    """Finish time of every canonical op when ops take real durations
    instead of unit ticks.  The per-rank op *order* is the schedule's
    (canonical tick order); each op starts at max(rank free, dependency
    finish) — list scheduling, so parity padding (dualpipe's alternating
    ticks) compacts away and only order + dependencies remain.

    Durations: F costs ``t_f``; under zb1p B costs ``t_b`` and W ``t_w``;
    schedules that do not split the backward run B as one ``t_b + t_w`` op.
    Interleaved chunk ops scale by ``1/v`` (a chunk holds ~1/v of a rank's
    layers; uniform-depth approximation)."""
    G = sched.n_stages
    scale = 1.0 / sched.n_chunks if sched.name == "interleaved" else 1.0
    split = sched.name == "zb1p"
    dur = {"F": t_f * scale,
           "B": (t_b if split else t_b + t_w) * scale,
           "W": t_w * scale}
    finish: Dict[Tuple[str, int, int], float] = {}
    rank_free = [0.0] * sched.pp
    for op in sched.ticks:          # sorted by canonical tick: deps first
        start = rank_free[op.rank]
        if op.op == "F" and op.stage > 0:
            start = max(start, finish[("F", op.micro, op.stage - 1)])
        elif op.op == "W":
            start = max(start, finish[("B", op.micro, op.stage)])
        elif op.op == "B":
            dep = ("F", op.micro, op.stage) if op.stage == G - 1 \
                else ("B", op.micro, op.stage + 1)
            start = max(start, finish[dep])
        f = start + dur[op.op]
        finish[(op.op, op.micro, op.stage)] = f
        rank_free[op.rank] = f
    return finish


@functools.lru_cache(maxsize=1024)
def bubble_stats(schedule: str, pp: int, n_micro: int, n_chunks: int = 1, *,
                 t_f: float = 1.0, t_b: float = 1.0, t_w: float = 1.0
                 ) -> BubbleStats:
    """Makespan, per-rank busy time and bubble fraction of the schedule's
    ideal (canonical-order, real-duration) timeline.  With the default
    ``t_f = t_b = t_w = 1`` every schedule does 3 units of work per micro
    per stage, so fractions are directly comparable: 1f1b's bubble ≈
    ``(pp-1)/(M+pp-1)`` and zb1p's shrinks toward a third of it."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    finish = weighted_finish_times(sched, t_f=t_f, t_b=t_b, t_w=t_w)
    makespan = max(finish.values())
    scale = 1.0 / sched.n_chunks if sched.name == "interleaved" else 1.0
    split = sched.name == "zb1p"
    dur = {"F": t_f * scale,
           "B": (t_b if split else t_b + t_w) * scale,
           "W": t_w * scale}
    busy = [0.0] * pp
    for op in sched.ticks:
        busy[op.rank] += dur[op.op]
    frac = 1.0 - sum(busy) / (pp * makespan)
    return BubbleStats(schedule=schedule, pp=pp, n_micro=n_micro,
                       n_chunks=sched.n_chunks, makespan=makespan,
                       busy=tuple(busy), bubble_fraction=frac)


def bubble_fraction(schedule: str, pp: int, n_micro: int,
                    n_chunks: int = 1, **kw) -> float:
    return bubble_stats(schedule, pp, n_micro, n_chunks, **kw).bubble_fraction


# ---------------------------------------------------------------------------
# Executor model: what the SPMD tick loop will measure
# ---------------------------------------------------------------------------

# Per-activity compute weights in chunk-forward units.  The fused
# chunk-recompute backward (slot checkpointing on) replays the forward and
# runs both gradient halves: 1 + 1 + 2 = 4F.  zb1p's B runs the same vjp
# *without* slot checkpointing — no replay, because instead of recomputing
# activations at W-time it stashes the fp32 pending-dW at B-time — so
# B ≈ 3F (dx + dW, replay skipped), and W is a pure stash→accumulator
# flush ≈ 0.25F.  Together ~3.25F against the fused 4F: zb1p does strictly
# less compute per microbatch *and* fills its cooldown with the cheap W
# flushes (the ZB trade, paid for in stash memory).
_W_F = 1.0
_W_B_FUSED = 4.0
_W_B_SPLIT = 3.0
_W_W = 0.25


@functools.lru_cache(maxsize=1024)
def exec_ticks(schedule: str, pp: int, n_micro: int,
               n_chunks: int = 1) -> int:
    """Tick count of the executor timeline (one cond-gated F + one
    cond-gated B — and, zb1p, dedicated cond-gated W ticks — per rank)."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    return max(exec_tick_times(sched).values()) + 1


@functools.lru_cache(maxsize=1024)
def exec_tick_ops(schedule: str, pp: int, n_micro: int,
                  n_chunks: int = 1) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """(T, pp) per-tick per-rank ``(nF, nB)`` op counts of the executor
    timeline — the collective-volume view :func:`predict_step_time` uses to
    price ZeRO-3's gather-on-use traffic (F all-gathers a chunk's params,
    B all-gathers then reduce-scatters the weight cotangent; zb1p's W is a
    pure stash flush with no parameter traffic, so it is not counted)."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    times = exec_tick_times(sched)
    T = max(times.values()) + 1
    counts = [[[0, 0] for _ in range(pp)] for _ in range(T)]
    for (op, m, g), t in times.items():
        r, _ = sched.owner(g, m)
        if op == "F":
            counts[t][r][0] += 1
        elif op == "B":
            counts[t][r][1] += 1
    return tuple(tuple((a, b) for a, b in row) for row in counts)


@functools.lru_cache(maxsize=1024)
def exec_tick_activity(schedule: str, pp: int, n_micro: int,
                       n_chunks: int = 1, w_b_split: float = _W_B_SPLIT
                       ) -> Tuple[Tuple[float, ...], ...]:
    """(T, pp) per-tick per-rank compute weight of the executor timeline,
    in chunk-forward units (F = 1, fused B = 4, zb1p's split B = 3 /
    W = 0.25).  Zero entries are the cond-gated no-op ticks the overlap
    engine skips; ``sum(1 for w in row if w)`` over a rank's column is its
    active-tick count — exactly M F-ticks + M B-ticks (+ M W-ticks under
    zb1p) per (rank, chunk).  ``w_b_split`` lets :func:`predict_step_time`
    substitute a host-adjusted weight for zb1p's no-remat B (the cache
    cliff, below) without disturbing the canonical table."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    times = exec_tick_times(sched)
    T = max(times.values()) + 1
    split = schedule == "zb1p"
    w = {"F": _W_F, "B": w_b_split if split else _W_B_FUSED, "W": _W_W}
    act = [[0.0] * pp for _ in range(T)]
    for (op, m, g), t in times.items():
        r, _ = sched.owner(g, m)
        act[t][r] += w[op]
    return tuple(tuple(row) for row in act)


@dataclasses.dataclass(frozen=True)
class StepTimePrediction:
    """Executor-model step time.  ``total_s = compute_s + comm_s +
    overhead_s``; ``ticks_active`` counts the (tick, rank) cells with any
    gated work (``ticks × pp`` minus the cond-skipped no-ops)."""

    schedule: str
    pp: int
    n_micro: int
    n_chunks: int
    view: str                       # 'overlapped' | 'masked'
    ticks: int
    ticks_active: int
    compute_s: float                # critical-rank compute, summed over ticks
    comm_s: float                   # exposed (overlapped) / serial (masked)
    overhead_s: float               # ticks × tick_overhead_s
    ideal_bubble_fraction: float    # the bubble_stats view, for the record

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s + self.overhead_s


def predict_step_time(spec: ModelSpec, schedule: str, pp: int,
                      n_micro: int, *, micro_batch: int, seq_len: int,
                      n_chunks: int = 1, tp: int = 1, sp: bool = False,
                      flops_per_s: float = NOMINAL_FLOPS_PER_S,
                      bytes_per_s: float = NOMINAL_BYTES_PER_S,
                      tick_overhead_s: float = 0.0,
                      serialize_ranks: bool = False,
                      cache_bytes: float = 0.0,
                      zero=None, dp: int = 1,
                      view: str = "overlapped") -> StepTimePrediction:
    """Predict what ``make_pipeline_train_step`` will measure for this
    (schedule, pp, tp, sp) on hardware with the given matmul throughput and
    memory/interconnect bandwidth.

    ``view="overlapped"`` (default) models the cond-gated overlap engine:
    per tick, the slowest rank's *active* compute (weights from
    :func:`exec_tick_activity`) with the boundary-ring traffic overlapped
    against it — a tick costs ``max(compute, comm)`` and idle ticks cost
    only the tick overhead.  ``view="masked"`` is the legacy pre-overlap
    executor: every tick burns one full chunk forward + one full
    chunk-recompute vjp on every rank, serial with the ring traffic.

    ``serialize_ranks=True`` adapts the overlapped view to a host whose
    "devices" share cores (the CPU fake-device harness: XLA runs the
    ranks' programs back-to-back, not concurrently): a tick then costs the
    *sum* of the ranks' active compute, not the max — schedule
    parallelism wins vanish and only total-work differences (zb1p's
    skipped recompute replay) and tick-count overhead remain measurable.
    The benchmark harness sets it from the host core count; the planner
    keeps the parallel default (it prices real accelerators).

    ``cache_bytes > 0`` (only meaningful with ``serialize_ranks``) adds
    the serializing host's cache cliff to that view: zb1p's no-remat B is
    only ~3F while the chunk vjp's saved intermediates stay resident in
    the core's cache — past the cliff every saved tensor is reloaded from
    memory at latency comparable to recomputing it, the replay saving is
    erased, and B is priced at the fused 4F (measured on the CPU harness:
    2-layer chunks fit a 2 MB L2 and keep the ~5% win, 4-layer chunks
    overflow it and tie).  Real accelerators stream saved activations
    from HBM on a compute-bound vjp, so the parallel view keeps B = 3
    unconditionally; the harness passes the host L2 size.

    Boundary ``ppermute`` payloads are ``b·s[/tp under sp]·h`` bf16, two
    rings for the down/up pair every schedule uses and four for dualpipe.
    Only *rankings* across schedules at fixed everything-else are
    load-bearing (CI's direction gate); absolute times need calibrated
    constants.

    ``zero="os+g+params"`` (a ``ZeROStage`` or its string value) with
    ``dp > 1`` prices ZeRO-3's gather-on-use traffic on top of the ring
    payloads: every F tick all-gathers one chunk's bf16 params over the
    DP group (``(dp-1)/dp`` of the full chunk crosses the wire) and every
    B tick pays the same all-gather plus the weight-cotangent
    reduce-scatter — per tick the slowest rank's volume (or the sum under
    ``serialize_ranks``) joins the comm the compute must hide.  This is
    the memory-for-comms trade the planner prices when ranking ZeRO-3
    configs."""
    if view not in ("overlapped", "masked"):
        raise ValueError(f"unknown executor view {view!r}")
    v = norm_chunks(schedule, n_chunks)
    ticks = exec_ticks(schedule, pp, n_micro, n_chunks=v)
    G = n_model_chunks(schedule, pp, v)
    l_chunk = math.ceil(spec.n_layers / G)
    w_b_split = _W_B_SPLIT
    if schedule == "zb1p" and serialize_ranks and cache_bytes > 0:
        from .activations import layer_activation_bytes
        from .parallel_config import ParallelConfig, RecomputePolicy
        cfg = ParallelConfig(tp=tp, sp=sp, micro_batch=micro_batch,
                             seq_len=seq_len,
                             recompute=RecomputePolicy.NONE)
        per_layer = sum(
            layer_activation_bytes(spec, cfg, l).per_layer
            for l in range(spec.n_layers)) / spec.n_layers
        if l_chunk * per_layer > cache_bytes:
            w_b_split = _W_B_FUSED     # past the cliff: saving erased
    acts = exec_tick_activity(schedule, pp, n_micro, n_chunks=v,
                              w_b_split=w_b_split)
    ticks_active = sum(1 for row in acts for w in row if w > 0)
    tokens = micro_batch * seq_len
    layers_fwd = sum(layer_fwd_flops(spec, l, tokens, seq_len)
                     for l in range(spec.n_layers)) / spec.n_layers
    head_fwd = 2.0 * tokens * spec.h * spec.vocab
    chunk_fwd = (l_chunk * layers_fwd + head_fwd) / tp / flops_per_s
    rings = 4 if schedule == "dualpipe" else 2
    payload = micro_batch * (seq_len // tp if sp else seq_len) * spec.h * 2
    comm_tick = rings * payload / bytes_per_s
    z3 = str(getattr(zero, "value", zero)) == "os+g+params" and dp > 1
    z3_f = z3_b = 0.0
    z3_ops = None
    if z3:
        from .activations import rank_chunk_layers
        from .parallel_config import ParallelConfig
        from .params import device_params
        cfgz = ParallelConfig(dp=dp, tp=tp, pp=pp, sp=sp,
                              micro_batch=micro_batch, seq_len=seq_len)
        chunk_layers = rank_chunk_layers(spec, pp, schedule=schedule,
                                         n_chunks=v)[0][0]
        chunk_bytes = device_params(spec, cfgz, layers=chunk_layers).total * 2
        ag = chunk_bytes * (dp - 1) / dp / bytes_per_s
        z3_f, z3_b = ag, 2 * ag        # F: gather; B: gather + grad scatter
        z3_ops = exec_tick_ops(schedule, pp, n_micro, n_chunks=v)
    if view == "overlapped":
        compute_s = 0.0
        comm_s = 0.0                # only the part compute cannot hide
        for i, row in enumerate(acts):
            c = (sum(row) if serialize_ranks else max(row)) * chunk_fwd
            ct = comm_tick
            if z3:
                per = [nf * z3_f + nb * z3_b for nf, nb in z3_ops[i]]
                ct += sum(per) if serialize_ranks else max(per)
            compute_s += c
            comm_s += max(0.0, ct - c)
    else:
        compute_s = ticks * (_W_F + _W_B_FUSED) * chunk_fwd
        comm_s = ticks * (comm_tick + z3_f + z3_b)
    ideal = bubble_fraction(schedule, pp, n_micro, v)
    return StepTimePrediction(
        schedule=schedule, pp=pp, n_micro=n_micro, n_chunks=v, view=view,
        ticks=ticks, ticks_active=ticks_active,
        compute_s=compute_s, comm_s=comm_s,
        overhead_s=ticks * tick_overhead_s,
        ideal_bubble_fraction=ideal)
