"""Analytic step-time / bubble model (the perf-trajectory layer).

The memory model answers "does it fit"; this module answers "how long is a
step" — at the same level of abstraction and from the same primitive, the
schedule tick stream of :mod:`core.schedules`.  Two views are provided, and
it matters which one a caller wants:

**Ideal timeline** (:func:`bubble_stats`): re-time the canonical per-rank op
order with real op durations — forward ``t_f``, input-gradient backward
``t_b``, weight-gradient ``t_w`` (schedules that do not split the backward
run B as one op of duration ``t_b + t_w``) — and report the makespan and
the bubble fraction ``1 - busy / (pp * makespan)``.  This is the number the
schedule literature quotes: with ``t_f = t_b = t_w``, 1f1b's bubble is
``2(pp-1)`` op-slots per rank and zb1p's collapses toward ``(pp-1)``
(ZB-H1's ``(p-1)(F+B-W)``, arXiv:2401.10241), which is *why* zero-bubble
schedules exist.

**Executor model** (:func:`predict_step_time`): what
``train.pipeline_loop``'s masked SPMD executor will actually measure.  That
executor burns one full (masked) chunk forward + one full (masked) chunk
vjp every tick on every rank regardless of the activity masks, so its wall
clock is ``T_exec × per-tick cost`` — schedules differ through their
executor tick count (``exec_tick_times``), their chunk depth (interleaved
halves layers per tick), their ring count (dualpipe permutes both
directions) and, for zb1p, the pending-gradient flush traffic.  On this
executor zb1p costs ``T_exec(1f1b) + 1`` ticks plus the flush — it cannot
*win* here; its bubble elimination pays off on hardware that skips masked
work.  The benchmark harness (``benchmarks/step_bench.py``) gates measured
rankings against THIS model, not the ideal one.

Also here: the analytic FLOPs the harness converts wall clock into MFU with
(:func:`model_fwd_flops` / :func:`step_flops` / :func:`mfu`), counting
dense-matmul + attention-score work per token, PaLM-appendix style.

Pure Python/numpy — ``core`` stays jax-free.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Tuple

from .notation import AttentionKind, ModelSpec
from .schedules import (PipelineSchedule, exec_tick_times, make_schedule,
                        n_model_chunks, norm_chunks)

# Nominal device constants for the executor model.  Rankings across
# schedules — the only thing CI asserts — are insensitive to them; the
# benchmark harness substitutes host-calibrated values for absolute
# predictions.  Defaults: A100-class bf16 peak and NVLink-class bandwidth.
NOMINAL_FLOPS_PER_S = 312e12
NOMINAL_BYTES_PER_S = 300e9


# ---------------------------------------------------------------------------
# Analytic FLOPs (MFU's denominator)
# ---------------------------------------------------------------------------

def layer_fwd_flops(spec: ModelSpec, layer_idx: int, tokens: int,
                    seq_len: int) -> float:
    """Forward FLOPs of transformer layer ``layer_idx`` for ``tokens``
    tokens at context ``seq_len``: 2 FLOPs per parameter per token for the
    projections (MoE layers count only the *active* experts + router), plus
    the attention score/value quadratic ``4·tokens·s·n_h·d`` (QKᵀ and A·V,
    causal masking not discounted — the kernels compute the full product).
    Norm/elementwise work is omitted (sub-percent)."""
    proj = spec.attn_params_per_layer(include_qk_norm=False)
    if spec.is_moe and layer_idx in spec.moe_layer_indices():
        proj += spec.moe_active_params_per_layer()
    elif spec.h_ff:
        proj += spec.dense_mlp_params_per_layer()
    if spec.ssm is not None:
        proj += spec.ssm_params_per_layer()
    flops = 2.0 * tokens * proj
    if spec.attention == AttentionKind.MLA:
        d_eff = spec.mla.d_h + spec.mla.d_hr
        flops += 4.0 * tokens * seq_len * spec.n_h * d_eff
    elif spec.attention != AttentionKind.NONE:
        flops += 4.0 * tokens * seq_len * spec.n_h * spec.d_head
    return flops


def model_fwd_flops(spec: ModelSpec, tokens: int, seq_len: int) -> float:
    """Forward FLOPs of the full model: all layers + the vocab head
    (``2·tokens·h·v``; the embedding lookup is free)."""
    flops = sum(layer_fwd_flops(spec, l, tokens, seq_len)
                for l in range(spec.n_layers))
    return flops + 2.0 * tokens * spec.h * spec.vocab


def step_flops(spec: ModelSpec, tokens: int, seq_len: int, *,
               recompute: bool = False) -> float:
    """Model FLOPs of one training step over ``tokens`` tokens: forward +
    2× forward for the backward (the PaLM-appendix 3× convention).  MFU
    deliberately excludes rematerialization — pass ``recompute=True`` only
    to price *hardware* FLOPs (e.g. the executor's chunk-recompute
    backward, a 4× multiplier)."""
    mult = 4.0 if recompute else 3.0
    return mult * model_fwd_flops(spec, tokens, seq_len)


def mfu(step_time_s: float, spec: ModelSpec, tokens: int, seq_len: int, *,
        peak_flops_per_s: float, n_devices: int = 1) -> float:
    """Model-FLOPs utilization: analytic step FLOPs (no recompute credit)
    over the hardware's peak across ``n_devices`` for ``step_time_s``."""
    if step_time_s <= 0 or peak_flops_per_s <= 0 or n_devices < 1:
        raise ValueError("mfu needs positive time, peak and device count")
    return step_flops(spec, tokens, seq_len) / (
        step_time_s * peak_flops_per_s * n_devices)


# ---------------------------------------------------------------------------
# Ideal timeline: weighted retiming of the canonical op order
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BubbleStats:
    """Weighted-retiming summary of one schedule's canonical timeline."""

    schedule: str
    pp: int
    n_micro: int
    n_chunks: int
    makespan: float                 # critical-path length, op-duration units
    busy: Tuple[float, ...]         # per-rank total op time
    bubble_fraction: float          # 1 - sum(busy) / (pp * makespan)


def weighted_finish_times(sched: PipelineSchedule, *, t_f: float = 1.0,
                          t_b: float = 1.0, t_w: float = 1.0
                          ) -> Dict[Tuple[str, int, int], float]:
    """Finish time of every canonical op when ops take real durations
    instead of unit ticks.  The per-rank op *order* is the schedule's
    (canonical tick order); each op starts at max(rank free, dependency
    finish) — list scheduling, so parity padding (dualpipe's alternating
    ticks) compacts away and only order + dependencies remain.

    Durations: F costs ``t_f``; under zb1p B costs ``t_b`` and W ``t_w``;
    schedules that do not split the backward run B as one ``t_b + t_w`` op.
    Interleaved chunk ops scale by ``1/v`` (a chunk holds ~1/v of a rank's
    layers; uniform-depth approximation)."""
    G = sched.n_stages
    scale = 1.0 / sched.n_chunks if sched.name == "interleaved" else 1.0
    split = sched.name == "zb1p"
    dur = {"F": t_f * scale,
           "B": (t_b if split else t_b + t_w) * scale,
           "W": t_w * scale}
    finish: Dict[Tuple[str, int, int], float] = {}
    rank_free = [0.0] * sched.pp
    for op in sched.ticks:          # sorted by canonical tick: deps first
        start = rank_free[op.rank]
        if op.op == "F" and op.stage > 0:
            start = max(start, finish[("F", op.micro, op.stage - 1)])
        elif op.op == "W":
            start = max(start, finish[("B", op.micro, op.stage)])
        elif op.op == "B":
            dep = ("F", op.micro, op.stage) if op.stage == G - 1 \
                else ("B", op.micro, op.stage + 1)
            start = max(start, finish[dep])
        f = start + dur[op.op]
        finish[(op.op, op.micro, op.stage)] = f
        rank_free[op.rank] = f
    return finish


@functools.lru_cache(maxsize=1024)
def bubble_stats(schedule: str, pp: int, n_micro: int, n_chunks: int = 1, *,
                 t_f: float = 1.0, t_b: float = 1.0, t_w: float = 1.0
                 ) -> BubbleStats:
    """Makespan, per-rank busy time and bubble fraction of the schedule's
    ideal (canonical-order, real-duration) timeline.  With the default
    ``t_f = t_b = t_w = 1`` every schedule does 3 units of work per micro
    per stage, so fractions are directly comparable: 1f1b's bubble ≈
    ``(pp-1)/(M+pp-1)`` and zb1p's shrinks toward a third of it."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    finish = weighted_finish_times(sched, t_f=t_f, t_b=t_b, t_w=t_w)
    makespan = max(finish.values())
    scale = 1.0 / sched.n_chunks if sched.name == "interleaved" else 1.0
    split = sched.name == "zb1p"
    dur = {"F": t_f * scale,
           "B": (t_b if split else t_b + t_w) * scale,
           "W": t_w * scale}
    busy = [0.0] * pp
    for op in sched.ticks:
        busy[op.rank] += dur[op.op]
    frac = 1.0 - sum(busy) / (pp * makespan)
    return BubbleStats(schedule=schedule, pp=pp, n_micro=n_micro,
                       n_chunks=sched.n_chunks, makespan=makespan,
                       busy=tuple(busy), bubble_fraction=frac)


def bubble_fraction(schedule: str, pp: int, n_micro: int,
                    n_chunks: int = 1, **kw) -> float:
    return bubble_stats(schedule, pp, n_micro, n_chunks, **kw).bubble_fraction


# ---------------------------------------------------------------------------
# Executor model: what the masked SPMD tick loop will measure
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1024)
def exec_ticks(schedule: str, pp: int, n_micro: int,
               n_chunks: int = 1) -> int:
    """Tick count of the executor timeline (one masked F + one masked B —
    and, zb1p, one masked W flush — per rank per tick)."""
    sched = make_schedule(schedule, pp, n_micro, n_chunks=n_chunks)
    return max(exec_tick_times(sched).values()) + 1


@dataclasses.dataclass(frozen=True)
class StepTimePrediction:
    """Executor-model step time, decomposed per tick.  ``total_s`` =
    ``ticks × (compute + comm + flush + overhead)``."""

    schedule: str
    pp: int
    n_micro: int
    n_chunks: int
    ticks: int
    compute_s_per_tick: float
    comm_s_per_tick: float
    flush_s_per_tick: float         # zb1p pending-gradient traffic; else 0
    overhead_s_per_tick: float
    ideal_bubble_fraction: float    # the bubble_stats view, for the record

    @property
    def total_s(self) -> float:
        return self.ticks * (self.compute_s_per_tick + self.comm_s_per_tick
                             + self.flush_s_per_tick
                             + self.overhead_s_per_tick)


def predict_step_time(spec: ModelSpec, schedule: str, pp: int,
                      n_micro: int, *, micro_batch: int, seq_len: int,
                      n_chunks: int = 1, tp: int = 1, sp: bool = False,
                      flops_per_s: float = NOMINAL_FLOPS_PER_S,
                      bytes_per_s: float = NOMINAL_BYTES_PER_S,
                      tick_overhead_s: float = 0.0) -> StepTimePrediction:
    """Predict what ``make_pipeline_train_step`` will measure for this
    (schedule, pp, tp, sp) on hardware with the given matmul throughput and
    memory/interconnect bandwidth.

    Per tick the executor runs one full chunk forward and one full chunk
    vjp (forward replay + 2× backward ≈ 3× forward) over the rank's
    ``l_max``-layer union slots *plus* the always-on embed/head/CE, TP
    dividing the matmul work; boundary ``ppermute`` payloads are
    ``b·s[/tp under sp]·h`` bf16, two rings for the down/up pair every
    schedule uses and four for dualpipe; zb1p adds the pending-stash
    read-modify-write (4× the chunk's fp32 grad bytes) every tick.  Only
    *rankings* across schedules at fixed everything-else are load-bearing
    (CI's direction gate); absolute times need calibrated constants."""
    v = norm_chunks(schedule, n_chunks)
    ticks = exec_ticks(schedule, pp, n_micro, n_chunks=v)
    G = n_model_chunks(schedule, pp, v)
    l_chunk = math.ceil(spec.n_layers / G)
    tokens = micro_batch * seq_len
    layers_fwd = sum(layer_fwd_flops(spec, l, tokens, seq_len)
                     for l in range(spec.n_layers)) / spec.n_layers
    head_fwd = 2.0 * tokens * spec.h * spec.vocab
    chunk_fwd = l_chunk * layers_fwd + head_fwd
    compute = 4.0 * chunk_fwd / tp / flops_per_s
    rings = 4 if schedule == "dualpipe" else 2
    payload = micro_batch * (seq_len // tp if sp else seq_len) * spec.h * 2
    comm = rings * payload / bytes_per_s
    flush = 0.0
    if schedule == "zb1p":
        chunk_params = sum(spec.layer_params(l)
                           for l in range(spec.n_layers)) \
            / spec.n_layers * l_chunk
        flush = 4.0 * (chunk_params * 4 / tp) / bytes_per_s
    ideal = bubble_fraction(schedule, pp, n_micro, v)
    return StepTimePrediction(
        schedule=schedule, pp=pp, n_micro=n_micro, n_chunks=v, ticks=ticks,
        compute_s_per_tick=compute, comm_s_per_tick=comm,
        flush_s_per_tick=flush, overhead_s_per_tick=tick_overhead_s,
        ideal_bubble_fraction=ideal)
