"""Activation-memory model (paper §5, Table 10) + extensions.

The paper derives per-layer activation bytes for the MLA and MoE blocks of
DeepSeek-v3 under TP2@SP2@CP1 with recomputation None / Full.  We implement
those formulas symbolically in (b, s, tp, sp, cp, ep, etp) so they reproduce
Table 10 exactly at the paper's settings, and extend the same accounting
discipline to the other assigned families (GQA/MQA attention, dense
SwiGLU/GeGLU/GELU MLPs, RWKV6 recurrence, hybrid layers, enc-dec).

Conventions (paper §5):
* bf16 activations → 2 bytes/value; masks/probabilities counted at the
  byte width the paper uses (5 b n_h s² = 2+2+1: scores, softmax, mask).
* SP divides sequence-resident tensors outside the TP regions; TP divides
  head/channel-sharded tensors; CP divides the sequence everywhere.
* MoE expert-side tensors use the balanced-routing estimate
  E_token = b·s·N_r / N  (paper §5.2), with N/EP local experts per rank and
  shared experts processing the full b·s tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .notation import AttentionKind, FamilyKind, MlpKind, ModelSpec
from .parallel_config import ParallelConfig, RecomputePolicy


@dataclasses.dataclass(frozen=True)
class ActivationBreakdown:
    attn: int          # MLA / GQA attention block
    mlp: int           # dense-MLP or MoE block (incl. router)
    ssm: int           # recurrent path
    per_layer: int     # attn + mlp + ssm (one layer)

    def scaled(self, n_layers: int) -> int:
        return self.per_layer * n_layers


# ---------------------------------------------------------------------------
# MLA (paper §5.1)
# ---------------------------------------------------------------------------

def mla_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy) -> int:
    """One layer of MLA activations (bytes).

    AC None (paper, TP@SP):
      M1 = 4bsh/sp + 2bs(d_cq+d_c) + 4bs(d_h+d_hr)n_h/tp + 2bs d_h n_h/tp
           + 5 b n_h s^2/tp + 2bs d_h n_h/tp + bsh/sp
    The 2bs(d_cq+d_c) latent tensors are NOT divided by sp because the down
    projections are replicated (paper).  AC Full: 2bsh/sp.
    """
    if spec.attention == AttentionKind.NONE:
        return 0
    m = spec.mla
    s = s // cp
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    scores = 5 * b * spec.n_h * s * s // tp
    none_total = (
        4 * b * s * spec.h // sp
        + 2 * b * s * (m.d_cq + m.d_c)
        + 4 * b * s * (m.d_h + m.d_hr) * spec.n_h // tp
        + 2 * b * s * m.d_v * spec.n_h // tp
        + scores
        + 2 * b * s * m.d_v * spec.n_h // tp
        + b * s * spec.h // sp
    )
    if recompute == RecomputePolicy.SELECTIVE:
        # selective = drop the O(s^2) score/softmax/mask tensors (flash-style)
        return none_total - scores
    return none_total


# ---------------------------------------------------------------------------
# MoE linear (paper §5.2)
# ---------------------------------------------------------------------------

def moe_activation_bytes(spec: ModelSpec, b: int, s: int, *, sp: int, cp: int,
                         ep: int, recompute: RecomputePolicy) -> int:
    """One MoE layer's activations (bytes), paper §5.2.

    AC None (SP@EP@ETP1):
      M1 = 4bsh/sp + 4bsN + 2bsN_r
           + n_local * (3 E_token h + 8 E_token h_E)
           + N_s * (3bsh + 8bs h_E)
    AC Full: bsh + 2 b s N_r  (input + router outputs kept).
    """
    e = spec.moe
    s = s // cp
    if recompute == RecomputePolicy.FULL:
        return b * s * spec.h + 2 * b * s * e.n_active
    n_local = e.n_routed // ep
    e_token = b * s * e.n_active / e.n_routed
    routed = n_local * (3 * e_token * spec.h + 8 * e_token * e.d_ff_expert)
    shared = e.n_shared * (3 * b * s * spec.h + 8 * b * s * e.d_ff_expert)
    total = (4 * b * s * spec.h // sp
             + 4 * b * s * e.n_routed
             + 2 * b * s * e.n_active
             + int(routed) + shared)
    if recompute == RecomputePolicy.SELECTIVE:
        # recompute expert FFN internals, keep dispatch/router/output
        total -= int(routed) + shared
        total += int(n_local * 2 * e_token * spec.h) + e.n_shared * 2 * b * s * spec.h
    return total


# ---------------------------------------------------------------------------
# Extensions: GQA attention, dense MLP, SSM (same accounting discipline)
# ---------------------------------------------------------------------------

def gqa_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy) -> int:
    """Standard MHA/GQA/MQA attention block, naive-softmax accounting to
    mirror the paper's 5 b n_h s² convention."""
    s = s // cp
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    d = spec.d_head
    kv_shard = min(tp, spec.n_kv)
    scores = 5 * b * spec.n_h * s * s // tp
    total = (
        2 * b * s * spec.h // sp                      # norm output (QKV input)
        + 2 * b * s * spec.n_h * d // tp              # Q
        + 2 * 2 * b * s * spec.n_kv * d // kv_shard   # K, V
        + scores
        + 2 * b * s * spec.n_h * d // tp              # attn context
        + b * s * spec.h // sp                        # o-proj output grad buffer
    )
    if recompute == RecomputePolicy.SELECTIVE:
        total -= scores
    return total


def dense_mlp_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int,
                               sp: int, cp: int,
                               recompute: RecomputePolicy) -> int:
    s = s // cp
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    inp = 2 * b * s * spec.h // sp
    if spec.mlp in (MlpKind.SWIGLU, MlpKind.GEGLU):
        hidden = 3 * 2 * b * s * spec.h_ff // tp      # gate, up, gated product
    else:
        hidden = 2 * 2 * b * s * spec.h_ff // tp      # fc1 out, act out
    return inp + hidden


def ssm_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy) -> int:
    """RWKV6/Mamba-style recurrent block.  The O(1)-in-s state is b·n_h·d·d;
    training stores the r/k/v/g/w projections (O(s)) unless recomputed."""
    if spec.ssm is None:
        return 0
    ss = spec.ssm
    s = s // cp
    d = spec.h * ss.ssm_expand
    state = 2 * b * ss.n_ssm_heads * (d // max(ss.n_ssm_heads, 1)) * ss.state_dim
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp + state
    proj = 5 * 2 * b * s * d // tp                    # r,k,v,g,w trajectories
    out = 2 * b * s * d // tp
    total = 2 * b * s * spec.h // sp + proj + out + state
    if recompute == RecomputePolicy.SELECTIVE:
        total -= out  # recompute the scan output from saved projections
    return total


# ---------------------------------------------------------------------------
# Per-layer / per-device composition
# ---------------------------------------------------------------------------

def layer_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                           layer_idx: int) -> ActivationBreakdown:
    b, s = cfg.micro_batch, cfg.seq_len
    kw = dict(tp=cfg.tp, sp=cfg.sp_degree, cp=cfg.cp, recompute=cfg.recompute)
    attn = 0
    if spec.attention == AttentionKind.MLA:
        attn = mla_activation_bytes(spec, b, s, **kw)
    elif spec.attention != AttentionKind.NONE:
        attn = gqa_activation_bytes(spec, b, s, **kw)
    ssm = ssm_activation_bytes(spec, b, s, **kw)
    if spec.is_moe and layer_idx in spec.moe_layer_indices():
        mlp = moe_activation_bytes(spec, b, s, sp=cfg.sp_degree, cp=cfg.cp,
                                   ep=cfg.ep, recompute=cfg.recompute)
    else:
        mlp = dense_mlp_activation_bytes(spec, b, s, **kw)
    return ActivationBreakdown(attn=attn, mlp=mlp, ssm=ssm,
                               per_layer=attn + mlp + ssm)


def one_f1b_in_flight(pp: int, stage: int, n_micro: Optional[int] = None) -> int:
    """In-flight (activation-resident) microbatches of PP ``stage`` under the
    1F1B schedule: stage s holds pp - s warmup forwards before its first
    backward frees one, capped by the number of microbatches.  Stage 0 is the
    worst case (pp in flight), the last stage holds exactly 1 — the
    stage-dependent multiplier the paper's §6 tables assume."""
    if not 0 <= stage < pp:
        raise ValueError(f"stage {stage} outside [0, {pp})")
    resident = pp - stage
    return min(n_micro, resident) if n_micro is not None else resident


def stage_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                           stage: int = None, in_flight: int = None) -> int:
    """Activation bytes held per device for one PP stage.

    ``in_flight`` microbatches are resident under 1F1B (stage_id-dependent,
    worst case = pp); default 1 reproduces the paper's single-microbatch
    Table 10 view.
    """
    from .params import table4_stages  # local import to avoid cycle
    stages = table4_stages(spec, cfg.pp)
    if stage is None:
        interior = [r for r in stages if 0 not in r.layers
                    and (spec.n_layers - 1) not in r.layers]
        row = max(interior or stages, key=lambda r: r.params)
    else:
        row = stages[stage]
    frac = cfg.recompute_fraction if cfg.recompute != RecomputePolicy.NONE \
        else 0.0
    n_rc = int(round(frac * len(row.layers)))
    no_rc = dataclasses.replace(cfg, recompute=RecomputePolicy.NONE)
    total = 0
    for i, l in enumerate(row.layers):
        c = cfg if i < n_rc else no_rc
        total += layer_activation_bytes(spec, c, l).per_layer
    return total * (in_flight or 1)


def table10(spec: ModelSpec, cfg: ParallelConfig) -> Dict[str, Dict[str, int]]:
    """Paper Table 10: MLA / MoE / total per 4-layer stage, AC None vs Full."""
    out: Dict[str, Dict[str, int]] = {}
    for policy in (RecomputePolicy.NONE, RecomputePolicy.FULL):
        c = dataclasses.replace(cfg, recompute=policy)
        b, s = c.micro_batch, c.seq_len
        kw = dict(tp=c.tp, sp=c.sp_degree, cp=c.cp, recompute=policy)
        mla = mla_activation_bytes(spec, b, s, **kw)
        moe = moe_activation_bytes(spec, b, s, sp=c.sp_degree, cp=c.cp,
                                   ep=c.ep, recompute=policy)
        out[policy.value] = {"MLA": 4 * mla, "MoE": 4 * moe,
                             "Total": 4 * (mla + moe)}
    return out
