"""Activation-memory model (paper §5, Table 10) + extensions.

The paper derives per-layer activation bytes for the MLA and MoE blocks of
DeepSeek-v3 under TP2@SP2@CP1 with recomputation None / Full.  We implement
those formulas symbolically in (b, s, tp, sp, cp, ep, etp) so they reproduce
Table 10 exactly at the paper's settings, and extend the same accounting
discipline to the other assigned families (GQA/MQA attention, dense
SwiGLU/GeGLU/GELU MLPs, RWKV6 recurrence, hybrid layers, enc-dec).

Conventions (paper §5):
* bf16 activations → 2 bytes/value; masks/probabilities counted at the
  byte width the paper uses (5 b n_h s² = 2+2+1: scores, softmax, mask).
* SP divides sequence-resident tensors outside the TP regions; TP divides
  head/channel-sharded tensors; CP divides the sequence everywhere.
* MoE expert-side tensors use the balanced-routing estimate
  E_token = b·s·N_r / N  (paper §5.2), with N/EP local experts per rank and
  shared experts processing the full b·s tokens.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, Optional

from .notation import AttentionKind, FamilyKind, MlpKind, ModelSpec
from .parallel_config import ParallelConfig, RecomputePolicy

# attn_impl values that never materialise the resident s² score buffers:
# the tiled kernel recomputes scores inside each layer's backward, so the
# 5·b·n_h·s² term drops from the activation stash.  "chunked" (the jnp
# lax.scan online-softmax) is deliberately NOT here — its scan residuals
# still store O(s²) under AD.
FLASH_ATTN_IMPLS = ("flash", "pallas")


def _shard_or_warn(dim: int, tp: int, what: str) -> int:
    """Effective TP divisor of a *channel/fused*-sharded dimension (qkv
    columns, ff hidden, ssm channels): ``tp`` when it divides exactly,
    else 1 (the tensor is replicated — same fallback as ``params._shard``)
    with a loud warning.  Before this guard the formulas silently
    floor-divided, which under-counted indivisible combos."""
    if tp <= 1:
        return 1
    if dim % tp == 0:
        return tp
    warnings.warn(
        f"tp={tp} does not divide {what}={dim}; modeling this tensor as "
        f"TP-replicated (the runtime's indivisible-dim fallback)",
        RuntimeWarning, stacklevel=3)
    return 1


def _seq_shard_or_warn(s: int, sp: int, what: str = "s") -> int:
    """Effective SP divisor of a *sequence-resident* tensor (the residual
    stream, norm inputs, boundary activations outside the TP regions):
    ``sp`` when it divides the (CP-local) sequence exactly, else 1 — the
    tensor stays SP-replicated — with a loud warning.  Before this guard
    the formulas silently floor-divided ``// sp``, under-counting
    indivisible sequence lengths; the executor's hard check is
    ``parallel.tp.check_sp_supported`` via ``notation.tp_violations(...,
    sp=..., seq_len=...)``."""
    if sp <= 1:
        return 1
    if s % sp == 0:
        return sp
    warnings.warn(
        f"sp={sp} does not divide {what}={s}; modeling sequence-resident "
        f"tensors as SP-replicated (the executor rejects this combo "
        f"outright — parallel.tp.check_sp_supported)",
        RuntimeWarning, stacklevel=3)
    return 1


def _head_shard_or_warn(n_heads: int, tp: int, what: str) -> int:
    """Effective TP divisor of a *head-count*-sharded tensor (the s²
    score/softmax buffers, laid out (b, n_h, s, s)): heads split evenly at
    most gcd(n_h, tp) ways.  The fused qkv columns may still shard the
    full ``tp`` ways (sub-head column splits — e.g. n_h=12 columns on a
    16-wide model axis), so this clamp applies only to the head-indexed
    tensors; warn loudly whenever the degree degrades."""
    if tp <= 1:
        return 1
    if n_heads % tp == 0:
        return tp
    g = math.gcd(n_heads, tp)
    warnings.warn(
        f"tp={tp} does not divide {what}={n_heads}; head-sharded score "
        f"tensors split at most gcd={g} ways (fused qkv columns still "
        f"shard tp ways when divisible)",
        RuntimeWarning, stacklevel=3)
    return g


@dataclasses.dataclass(frozen=True)
class ActivationBreakdown:
    attn: int          # MLA / GQA attention block
    mlp: int           # dense-MLP or MoE block (incl. router)
    ssm: int           # recurrent path
    per_layer: int     # attn + mlp + ssm (one layer)

    def scaled(self, n_layers: int) -> int:
        return self.per_layer * n_layers


# ---------------------------------------------------------------------------
# MLA (paper §5.1)
# ---------------------------------------------------------------------------

def mla_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy,
                         attn_impl: str = "naive") -> int:
    """One layer of MLA activations (bytes).

    AC None (paper, TP@SP):
      M1 = 4bsh/sp + 2bs(d_cq+d_c) + 4bs(d_h+d_hr)n_h/tp + 2bs d_h n_h/tp
           + 5 b n_h s^2/tp + 2bs d_h n_h/tp + bsh/sp
    The 2bs(d_cq+d_c) latent tensors are NOT divided by sp because the down
    projections are replicated (paper).  AC Full: 2bsh/sp.

    ``attn_impl`` in ``FLASH_ATTN_IMPLS`` drops exactly the 5·b·n_h·s²
    score/softmax/mask term at AC-None — the tiled kernel keeps the s²
    blocks transient inside each layer's fwd/bwd.  At SELECTIVE the term
    is already gone, so flash changes nothing (no double subtraction).
    """
    if spec.attention == AttentionKind.NONE:
        return 0
    m = spec.mla
    s = s // cp
    sp = _seq_shard_or_warn(s, sp)
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    tp_c = _shard_or_warn(spec.n_h * m.d_h, tp, "n_h*d_h")
    scores = 5 * b * spec.n_h * s * s \
        // _head_shard_or_warn(spec.n_h, tp, "n_h")
    none_total = (
        4 * b * s * spec.h // sp
        + 2 * b * s * (m.d_cq + m.d_c)
        + 4 * b * s * (m.d_h + m.d_hr) * spec.n_h // tp_c
        + 2 * b * s * m.d_v * spec.n_h // tp_c
        + scores
        + 2 * b * s * m.d_v * spec.n_h // tp_c
        + b * s * spec.h // sp
    )
    if recompute == RecomputePolicy.SELECTIVE \
            or attn_impl in FLASH_ATTN_IMPLS:
        # drop the O(s^2) score/softmax/mask tensors (flash-style)
        return none_total - scores
    return none_total


# ---------------------------------------------------------------------------
# MoE linear (paper §5.2)
# ---------------------------------------------------------------------------

def moe_activation_bytes(spec: ModelSpec, b: int, s: int, *, sp: int, cp: int,
                         ep: int, recompute: RecomputePolicy) -> int:
    """One MoE layer's activations (bytes), paper §5.2.

    AC None (SP@EP@ETP1):
      M1 = 4bsh/sp + 4bsN + 2bsN_r
           + n_local * (3 E_token h + 8 E_token h_E)
           + N_s * (3bsh + 8bs h_E)
    AC Full: bsh + 2 b s N_r  (input + router outputs kept).
    """
    e = spec.moe
    s = s // cp
    sp = _seq_shard_or_warn(s, sp)
    if recompute == RecomputePolicy.FULL:
        return b * s * spec.h + 2 * b * s * e.n_active
    n_local = e.n_routed // _shard_or_warn(e.n_routed, ep, "n_routed (EP)")
    e_token = b * s * e.n_active / e.n_routed
    routed = n_local * (3 * e_token * spec.h + 8 * e_token * e.d_ff_expert)
    shared = e.n_shared * (3 * b * s * spec.h + 8 * b * s * e.d_ff_expert)
    total = (4 * b * s * spec.h // sp
             + 4 * b * s * e.n_routed
             + 2 * b * s * e.n_active
             + int(routed) + shared)
    if recompute == RecomputePolicy.SELECTIVE:
        # recompute expert FFN internals, keep dispatch/router/output
        total -= int(routed) + shared
        total += int(n_local * 2 * e_token * spec.h) + e.n_shared * 2 * b * s * spec.h
    return total


# ---------------------------------------------------------------------------
# Extensions: GQA attention, dense MLP, SSM (same accounting discipline)
# ---------------------------------------------------------------------------

def gqa_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy,
                         attn_impl: str = "naive") -> int:
    """Standard MHA/GQA/MQA attention block, naive-softmax accounting to
    mirror the paper's 5 b n_h s² convention.  ``attn_impl`` in
    ``FLASH_ATTN_IMPLS`` drops the s² term at AC-None (see
    ``mla_activation_bytes``)."""
    s = s // cp
    sp = _seq_shard_or_warn(s, sp)
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    d = spec.d_head
    tp_c = _shard_or_warn(spec.n_h * d, tp, "n_h*d_head")
    # kv-head clamp: K/V shard at most n_kv ways (min(tp, n_kv) — the same
    # clamp kv_cache_bytes applies on the decode path), degrading to
    # gcd when the clamped degree doesn't divide n_kv
    kv_shard = min(tp, spec.n_kv)
    if kv_shard > 1 and spec.n_kv % kv_shard:
        kv_shard = _head_shard_or_warn(spec.n_kv, kv_shard, "n_kv")
    scores = 5 * b * spec.n_h * s * s \
        // _head_shard_or_warn(spec.n_h, tp, "n_h")
    total = (
        2 * b * s * spec.h // sp                      # norm output (QKV input)
        + 2 * b * s * spec.n_h * d // tp_c            # Q
        + 2 * 2 * b * s * spec.n_kv * d // kv_shard   # K, V
        + scores
        + 2 * b * s * spec.n_h * d // tp_c            # attn context
        + b * s * spec.h // sp                        # o-proj output grad buffer
    )
    if recompute == RecomputePolicy.SELECTIVE \
            or attn_impl in FLASH_ATTN_IMPLS:
        total -= scores
    return total


def dense_mlp_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int,
                               sp: int, cp: int,
                               recompute: RecomputePolicy) -> int:
    s = s // cp
    sp = _seq_shard_or_warn(s, sp)
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp
    tp = _shard_or_warn(spec.h_ff, tp, "h_ff") if spec.h_ff else 1
    inp = 2 * b * s * spec.h // sp
    if spec.mlp in (MlpKind.SWIGLU, MlpKind.GEGLU):
        hidden = 3 * 2 * b * s * spec.h_ff // tp      # gate, up, gated product
    else:
        hidden = 2 * 2 * b * s * spec.h_ff // tp      # fc1 out, act out
    return inp + hidden


def ssm_activation_bytes(spec: ModelSpec, b: int, s: int, *, tp: int, sp: int,
                         cp: int, recompute: RecomputePolicy) -> int:
    """RWKV6/Mamba-style recurrent block.  The O(1)-in-s state is b·n_h·d·d;
    training stores the r/k/v/g/w projections (O(s)) unless recomputed."""
    if spec.ssm is None:
        return 0
    ss = spec.ssm
    s = s // cp
    sp = _seq_shard_or_warn(s, sp)
    d = spec.h * ss.ssm_expand
    state = 2 * b * ss.n_ssm_heads * (d // max(ss.n_ssm_heads, 1)) * ss.state_dim
    if recompute == RecomputePolicy.FULL:
        return 2 * b * s * spec.h // sp + state
    tp = _shard_or_warn(d, tp, "ssm channel dim")
    proj = 5 * 2 * b * s * d // tp                    # r,k,v,g,w trajectories
    out = 2 * b * s * d // tp
    total = 2 * b * s * spec.h // sp + proj + out + state
    if recompute == RecomputePolicy.SELECTIVE:
        total -= out  # recompute the scan output from saved projections
    return total


# ---------------------------------------------------------------------------
# Per-layer / per-device composition
# ---------------------------------------------------------------------------

def layer_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                           layer_idx: int) -> ActivationBreakdown:
    b, s = cfg.micro_batch, cfg.seq_len
    kw = dict(tp=cfg.tp, sp=cfg.sp_degree, cp=cfg.cp, recompute=cfg.recompute)
    # attn_impl only reshapes the attention block's s² accounting
    akw = dict(kw, attn_impl=cfg.attn_impl)
    attn = 0
    if spec.attention == AttentionKind.MLA:
        attn = mla_activation_bytes(spec, b, s, **akw)
    elif spec.attention != AttentionKind.NONE:
        attn = gqa_activation_bytes(spec, b, s, **akw)
    ssm = ssm_activation_bytes(spec, b, s, **kw)
    if spec.is_moe and layer_idx in spec.moe_layer_indices():
        mlp = moe_activation_bytes(spec, b, s, sp=cfg.sp_degree, cp=cfg.cp,
                                   ep=cfg.ep, recompute=cfg.recompute)
    else:
        mlp = dense_mlp_activation_bytes(spec, b, s, **kw)
    return ActivationBreakdown(attn=attn, mlp=mlp, ssm=ssm,
                               per_layer=attn + mlp + ssm)


def one_f1b_in_flight(pp: int, stage: int, n_micro: Optional[int] = None) -> int:
    """In-flight (activation-resident) microbatches of PP ``stage`` under the
    1F1B schedule: stage s holds pp - s warmup forwards before its first
    backward frees one, capped by the number of microbatches.  Stage 0 is the
    worst case (pp in flight), the last stage holds exactly 1 — the
    stage-dependent multiplier the paper's §6 tables assume.

    Kept as the canonical special case; ``schedule_in_flight`` generalizes it
    across schedules."""
    return schedule_in_flight(pp, stage, n_micro, schedule="1f1b")


def schedule_in_flight(pp: int, rank: int, n_micro: Optional[int] = None, *,
                       schedule: str = "1f1b", n_chunks: int = 1) -> int:
    """Peak in-flight (activation-resident) microbatch×chunk units on PP
    ``rank`` under ``schedule`` — the closed forms the tick simulator
    (``core.schedules``) is property-tested against:

    * ``1f1b``:        min(M, pp - rank)
    * ``interleaved``: min(M·v, (v-1)·pp + 2·(pp - rank - 1) + 1)
      (each unit is one of the rank's v *chunks*, ~1/v of its layers)
    * ``dualpipe``:    min(⌈M/2⌉, pp - rank) + min(⌊M/2⌋, rank + 1)
      (≈ pp + 1 on every rank — DualPipe's near-flat profile)
    * ``zb1p``:        min(M, pp - rank) — same as 1f1b: the full-layer
      activation stash still retires at B (which runs the whole vjp); the
      deferred W ops instead park each pending microbatch's fp32
      pending-dW in the executor's stash ring until the W flush
      (``core.schedules.zb_pending_peak``), priced as grad memory by
      ``estimate_memory(schedule="zb1p")``

    ``n_micro=None`` gives the M→∞ steady-state value.
    """
    from .schedules import norm_chunks  # shared validation
    if not 0 <= rank < pp:
        raise ValueError(f"rank {rank} outside [0, {pp})")
    v = norm_chunks(schedule, n_chunks)
    if schedule in ("1f1b", "zb1p"):
        resident = pp - rank
        return min(n_micro, resident) if n_micro is not None else resident
    if schedule == "interleaved":
        resident = (v - 1) * pp + 2 * (pp - rank - 1) + 1
        return min(n_micro * v, resident) if n_micro is not None else resident
    # dualpipe
    ma = (n_micro + 1) // 2 if n_micro is not None else pp
    mb = n_micro // 2 if n_micro is not None else pp
    return min(ma, pp - rank) + min(mb, rank + 1)


def layers_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                             layers) -> int:
    """Activation bytes of one microbatch across ``layers``, applying the
    recompute policy to the first ``recompute_fraction`` of them (paper §5's
    'how many layers to recompute')."""
    frac = cfg.recompute_fraction if cfg.recompute != RecomputePolicy.NONE \
        else 0.0
    n_rc = int(round(frac * len(layers)))
    no_rc = dataclasses.replace(cfg, recompute=RecomputePolicy.NONE)
    total = 0
    for i, l in enumerate(layers):
        c = cfg if i < n_rc else no_rc
        total += layer_activation_bytes(spec, c, l).per_layer
    return total


def stage_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                           stage: int = None, in_flight: int = None) -> int:
    """Activation bytes held per device for one PP stage.

    ``in_flight`` microbatches are resident under 1F1B (stage_id-dependent,
    worst case = pp); default 1 reproduces the paper's single-microbatch
    Table 10 view.
    """
    from .params import table4_stages  # local import to avoid cycle
    stages = table4_stages(spec, cfg.pp)
    if stage is None:
        interior = [r for r in stages if 0 not in r.layers
                    and (spec.n_layers - 1) not in r.layers]
        row = max(interior or stages, key=lambda r: r.params)
    else:
        row = stages[stage]
    return layers_activation_bytes(spec, cfg, row.layers) * (in_flight or 1)


def rank_chunk_layers(spec: ModelSpec, pp: int, *, schedule: str = "1f1b",
                      n_chunks: int = 1):
    """Per-rank tuple of layer-id tuples, one per local chunk: the model is
    split into ``n_model_chunks`` contiguous pieces with the same Table-4
    front-loaded rule as plain PP (``params.pp_stage_layers``), then placed
    by ``core.schedules.schedule_placement``.  Under dualpipe every model
    chunk appears on two ranks (the schedule's 2× parameter cost)."""
    from .params import pp_stage_layers
    from .schedules import n_model_chunks, schedule_placement
    if schedule == "dualpipe" and pp < 2:
        raise ValueError("dualpipe needs pp >= 2 (pp=1 would duplicate the "
                         "whole model onto one rank)")
    g = n_model_chunks(schedule, pp, n_chunks)
    if g > spec.n_layers:
        raise ValueError(f"{g} model chunks need n_layers >= {g} "
                         f"(got {spec.n_layers})")
    pieces = pp_stage_layers(spec.n_layers, g)
    placement = schedule_placement(schedule, pp, n_chunks)
    return tuple(tuple(tuple(pieces[cid]) for cid in row)
                 for row in placement)


def schedule_activation_bytes(spec: ModelSpec, cfg: ParallelConfig,
                              rank: int, *, schedule: str = "1f1b",
                              n_chunks: int = 1,
                              n_micro: Optional[int] = None) -> int:
    """Schedule-aware peak activation residency (bytes) on PP ``rank``.

    Time-resolved: the tick simulator gives each chunk's in-flight count
    k_c(t); the reported peak is max_t Σ_c k_c(t)·bytes(chunk c), which is
    ≤ the sum of per-chunk peaks (chunks of a rank do not all peak at the
    same tick under interleaving).  For 1f1b this reduces exactly to
    ``stage_activation_bytes(stage=rank, in_flight=min(M, pp-rank))``.

    ``n_micro=None`` uses M = 2·pp (rounded up to a pp multiple), enough to
    reach every schedule's steady-state plateau.
    """
    from .schedules import make_schedule
    pp = cfg.pp
    if n_micro is None:
        n_micro = 2 * pp
    chunks = rank_chunk_layers(spec, pp, schedule=schedule,
                               n_chunks=n_chunks)[rank]
    weights = [layers_activation_bytes(spec, cfg, ls) for ls in chunks]
    if pp == 1:
        return sum(weights)          # no pipeline: one microbatch resident
    sched = make_schedule(schedule, pp, n_micro, n_chunks=len(chunks))
    peak, _ = sched.peak_profile(rank, weights)
    return int(peak)


def table10(spec: ModelSpec, cfg: ParallelConfig) -> Dict[str, Dict[str, int]]:
    """Paper Table 10: MLA / MoE / total per 4-layer stage, AC None vs Full."""
    out: Dict[str, Dict[str, int]] = {}
    for policy in (RecomputePolicy.NONE, RecomputePolicy.FULL):
        c = dataclasses.replace(cfg, recompute=policy)
        b, s = c.micro_batch, c.seq_len
        kw = dict(tp=c.tp, sp=c.sp_degree, cp=c.cp, recompute=policy,
                  attn_impl=c.attn_impl)
        mla = mla_activation_bytes(spec, b, s, **kw)
        moe = moe_activation_bytes(spec, b, s, sp=c.sp_degree, cp=c.cp,
                                   ep=c.ep, recompute=policy)
        out[policy.value] = {"MLA": 4 * mla, "MoE": 4 * moe,
                             "Total": 4 * (mla + moe)}
    return out
