"""DeepSpeed-ZeRO memory math (paper §4, Table 8).

ZeRO shards training state across the gradient-sync group.  Because expert
parameters sync across EDP (not DP), the expert and non-expert parts shard
with different divisors — the central observation of paper §4:

    per_device = non_expert/DP + expert/EDP     (times bytes-per-param)

Byte multipliers come from Table 7: weights 2 B, gradients 4 B, optimizer
8 B (fp32 master + bf16 momentum + bf16 variance).  Note the paper's §4 prose
swaps the gradient/optimizer multipliers; Tables 7 and 8 are self-consistent
and we follow the tables (see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from .notation import ModelSpec
from .params import DeviceParams, device_params
from .parallel_config import ParallelConfig, ZeROStage


@dataclasses.dataclass(frozen=True)
class TrainStateBytes:
    params: int
    grads: int
    optimizer: int

    @property
    def total(self) -> int:
        return self.params + self.grads + self.optimizer


def _sharded(dev: DeviceParams, cfg: ParallelConfig, bytes_per: int) -> int:
    # Ceil division: a rank's shard is ceil(n/group) params — floor would
    # under-count per-device bytes whenever the group doesn't divide n.
    return (-(-dev.non_expert // cfg.dp) + -(-dev.expert // cfg.edp)) * bytes_per


def zero_memory(spec: ModelSpec, cfg: ParallelConfig,
                stage: int = None, layers=None) -> TrainStateBytes:
    """Per-device bytes of params/grads/optimizer for one PP stage (or, via
    ``layers``, an explicit layer-id list — the schedule-aware multi-chunk
    path)."""
    dev = device_params(spec, cfg, stage=stage, layers=layers)
    dt = cfg.dtype
    full_p = dev.total * dt.weights
    full_g = dev.total * dt.gradient
    full_o = dev.total * dt.optimizer

    z = cfg.zero
    opt = _sharded(dev, cfg, dt.optimizer) if z != ZeROStage.NONE else full_o
    grads = _sharded(dev, cfg, dt.gradient) \
        if z in (ZeROStage.OS_G, ZeROStage.OS_G_PARAMS) else full_g
    params = _sharded(dev, cfg, dt.weights) \
        if z == ZeROStage.OS_G_PARAMS else full_p
    return TrainStateBytes(params=params, grads=grads, optimizer=opt)


def zero_table(spec: ModelSpec, cfg: ParallelConfig) -> Dict[str, TrainStateBytes]:
    """Paper Table 8: all four ZeRO strategies for the given config."""
    out = {}
    for z in ZeROStage:
        c = dataclasses.replace(cfg, zero=z)
        out[z.value] = zero_memory(spec, c)
    return out
