"""Model-architecture notation (paper Table 1/2, generalized to 6 families).

The paper analyses DeepSeek-v3; the assigned-architecture pool additionally
spans dense (GQA/MQA), MoE (standard SwiGLU experts), SSM (RWKV6), hybrid
(Hymba: parallel attention+SSM heads), enc-dec audio (Whisper) and VLM
(Qwen2-VL decoder).  ``ModelSpec`` is the single structural description that
both the analytical memory model (``repro.core``) and the runtime model
builder (``repro.models``) consume, so the two can never drift apart.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class AttentionKind(enum.Enum):
    """Which attention mechanism a layer uses."""

    MHA = "mha"            # n_kv == n_h
    GQA = "gqa"            # 1 < n_kv < n_h
    MQA = "mqa"            # n_kv == 1
    MLA = "mla"            # DeepSeek multi-head latent attention
    NONE = "none"          # attention-free (pure SSM)


class MlpKind(enum.Enum):
    SWIGLU = "swiglu"      # gate/up/down, 3 matrices (DeepSeek, Qwen, OLMoE)
    GEGLU = "geglu"        # gate/up/down with GeLU (Gemma)
    GELU = "gelu"          # fc1/fc2, 2 matrices (Whisper)


class FamilyKind(enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"      # parallel attention + SSM heads (Hymba)
    AUDIO = "audio"        # encoder-decoder (Whisper)
    VLM = "vlm"            # dense decoder consuming patch embeddings


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """Multi-head latent attention dimensions (paper Table 1)."""

    d_cq: int = 1536       # query compression dim (q_lora_rank)
    d_c: int = 512         # key-value compression dim (kv_lora_rank)
    d_h: int = 128         # qk_nope_head_dim
    d_hr: int = 64         # qk_rope_head_dim
    d_v: int = 128         # v_head_dim


@dataclasses.dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts dimensions (paper Table 1)."""

    n_routed: int          # N   — routed experts per MoE layer
    n_active: int          # N_r — routed experts per token (top-k)
    n_shared: int = 0      # N_s — shared experts (always-on)
    d_ff_expert: int = 0   # h_E — expert MLP hidden dim
    # layers [0, first_k_dense) use a dense FFN instead of MoE (DeepSeek: 3).
    first_k_dense: int = 0
    router_bias: bool = False


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """State-space / RWKV recurrent path dimensions."""

    state_dim: int         # per-head recurrent state size (rwkv head dim / mamba d_state)
    n_ssm_heads: int       # number of recurrent heads
    conv_kernel: int = 0   # depthwise conv width (mamba-style); 0 = none
    ssm_expand: int = 1    # channel expansion factor of the recurrent block


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Encoder tower of an enc-dec model (Whisper). Frontend is stubbed."""

    n_layers: int
    n_ctx: int             # encoder sequence length (whisper: 1500)
    frontend: str = "stub" # mel+conv stub: input_specs supplies embeddings


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Complete structural description of one architecture.

    Field names follow the paper's notation where one exists:
    ``h`` hidden dim, ``h_ff`` dense-MLP hidden (h_F), ``n_h`` heads,
    ``d_h`` head dim, ``n_layers`` (l), ``vocab`` (v).
    """

    name: str
    family: FamilyKind
    n_layers: int
    h: int
    n_h: int
    n_kv: int
    d_head: int
    h_ff: int
    vocab: int
    attention: AttentionKind = AttentionKind.GQA
    mlp: MlpKind = MlpKind.SWIGLU
    mla: Optional[MLASpec] = None
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    encoder: Optional[EncoderSpec] = None
    tie_embeddings: bool = False
    qkv_bias: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    # Sliding-window decode variant (enables long_500k for full-attention archs).
    sliding_window: Optional[int] = None
    max_seq_len: int = 32768
    notes: str = ""

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def attn_free(self) -> bool:
        return self.attention == AttentionKind.NONE

    def moe_layer_indices(self) -> Tuple[int, ...]:
        if not self.is_moe:
            return ()
        return tuple(range(self.moe.first_k_dense, self.n_layers))

    def n_moe_layers(self) -> int:
        return len(self.moe_layer_indices())

    def n_dense_layers(self) -> int:
        return self.n_layers - self.n_moe_layers()

    # -- parameter counts (exact; used by core.params and asserted in tests)

    def attn_params_per_layer(self, include_qk_norm: bool = True) -> int:
        """Parameters of one attention block (projections (+biases) only)."""
        if self.attention == AttentionKind.MLA:
            m = self.mla
            tp_split = (
                m.d_h * self.n_h * m.d_cq        # W^UQ
                + m.d_h * self.n_h * m.d_c       # W^UK
                + m.d_v * self.n_h * m.d_c       # W^UV
                + self.h * m.d_v * self.n_h      # W^O
            )
            replicated = (
                m.d_cq * self.h                  # W^DQ
                + m.d_c * self.h                 # W^DKV
                + m.d_hr * self.n_h * m.d_cq     # W^QR
                + m.d_hr * self.h                # W^KR
            )
            total = tp_split + replicated
            if include_qk_norm:
                total += m.d_cq + m.d_c          # q/kv RMSNorm (paper Table 3)
            return total
        if self.attention == AttentionKind.NONE:
            return 0
        q = self.h * self.n_h * self.d_head
        kv = 2 * self.h * self.n_kv * self.d_head
        o = self.n_h * self.d_head * self.h
        bias = 0
        if self.qkv_bias:
            bias = self.n_h * self.d_head + 2 * self.n_kv * self.d_head
        return q + kv + o + bias

    def mlp_params(self, d_ff: int) -> int:
        if self.mlp in (MlpKind.SWIGLU, MlpKind.GEGLU):
            return 3 * self.h * d_ff
        return 2 * self.h * d_ff          # GELU: fc1 + fc2

    def dense_mlp_params_per_layer(self) -> int:
        return self.mlp_params(self.h_ff)

    def moe_params_per_layer(self) -> int:
        """Router (gate) + all experts of one MoE layer."""
        if not self.is_moe:
            return 0
        e = self.moe
        router = e.n_routed * self.h + (e.n_routed if e.router_bias else 0)
        experts = 3 * self.h * e.d_ff_expert * (e.n_routed + e.n_shared)
        return router + experts

    def moe_active_params_per_layer(self) -> int:
        if not self.is_moe:
            return 0
        e = self.moe
        router = e.n_routed * self.h
        experts = 3 * self.h * e.d_ff_expert * (e.n_active + e.n_shared)
        return router + experts

    def ssm_params_per_layer(self) -> int:
        """RWKV6-style time-mix block (approximate but consistent w/ runtime)."""
        if self.ssm is None:
            return 0
        s = self.ssm
        d = self.h * s.ssm_expand
        # r/k/v/g/o projections + data-dependent decay low-rank (w1,w2) + u
        proj = 5 * self.h * d
        decay = self.h * 64 + 64 * d + d       # lora-style decay + per-channel u
        tokenshift = 6 * self.h                # per-channel interpolation mus
        conv = s.conv_kernel * d if s.conv_kernel else 0
        return proj + decay + tokenshift + conv

    def norm_params_per_layer(self) -> int:
        n = 2 * self.h
        if self.attention == AttentionKind.MLA:
            n += self.mla.d_cq + self.mla.d_c   # counted in LN row by the paper
        return n

    def embedding_params(self) -> int:
        return self.vocab * self.h

    def layer_params(self, layer_idx: int) -> int:
        """Total parameters of transformer layer ``layer_idx`` (no emb/head).

        Matches paper Table 3 semantics: MLA row includes qk-norms, LN row
        counts them again (paper double-count reproduced via report.py, not
        here — here each param is counted once).
        """
        p = self.attn_params_per_layer(include_qk_norm=False)
        p += self.norm_params_per_layer()
        if self.ssm is not None:
            p += self.ssm_params_per_layer()
            if self.family == FamilyKind.HYBRID:
                p += self.h  # extra norm merging parallel heads
        if self.is_moe and layer_idx in self.moe_layer_indices():
            p += self.moe_params_per_layer()
        elif self.h_ff:
            p += self.dense_mlp_params_per_layer()
        return p

    def total_params(self) -> int:
        body = sum(self.layer_params(i) for i in range(self.n_layers))
        emb = self.embedding_params()
        head = 0 if self.tie_embeddings else self.embedding_params()
        final_norm = self.h
        enc = 0
        if self.encoder is not None:
            # encoder layers: MHA + GELU MLP + norms (+ cross-attn lives in decoder)
            per = (4 * self.h * self.h) + self.mlp_params(self.h_ff) + 2 * self.h
            enc = self.encoder.n_layers * per + self.h
            # decoder cross-attention adds 4*h*h + its layernorm per layer
            body += self.n_layers * (4 * self.h * self.h + self.h)
        return body + emb + head + final_norm + enc

    def active_params(self) -> int:
        """Activated parameters per token (= total for non-MoE)."""
        if not self.is_moe:
            return self.total_params()
        per_layer_delta = self.moe_params_per_layer() - self.moe_active_params_per_layer()
        return self.total_params() - per_layer_delta * self.n_moe_layers()


def tp_violations(spec: "ModelSpec", tp: int, *, sp: int = 1,
                  seq_len: Optional[int] = None, ep: int = 1,
                  attn_impl: str = "naive"):
    """Dims a TP degree fails to divide exactly, as human-readable strings
    (empty list = cleanly divisible).  Shared by the analytic guard
    (``core.activations``), the planner's runnable marking and the
    executor's hard checks (``parallel.tp.check_tp_supported`` /
    ``check_sp_supported`` / ``check_ep_supported``).

    ``sp``/``seq_len`` extend the check to sequence parallelism: SP shards
    the token dim, so ``seq_len % sp`` must be 0 (the executor's boundary
    all-gather/reduce-scatter pair has no replicate-fallback; the analytic
    model falls back to SP-replicated accounting with a RuntimeWarning —
    ``core.activations._seq_shard_or_warn``).

    ``ep`` extends it to expert parallelism: the expert-dim weight shard
    requires ``n_routed % ep == 0`` (the analytic fallback is
    EP-replicated accounting — ``core.activations._shard_or_warn``).

    ``attn_impl`` in ``("flash", "pallas")`` extends it to the flash
    kernel's tiling: block_q = min(128, s) must divide the sequence the
    kernel sees (the FULL sequence — SP gathers before attention) — the
    kernel pads internally, but the analytic model does not price pad
    blocks, so the executor refuses padded-flash configs."""
    bad = []
    if sp > 1 and seq_len is not None and seq_len % sp:
        bad.append(f"s={seq_len} (sp={sp})")
    if attn_impl in ("flash", "pallas") and seq_len is not None \
            and spec.attention != AttentionKind.NONE:
        bq = min(128, seq_len)
        if seq_len % bq:
            bad.append(f"s={seq_len} (flash block_q={bq})")
    if ep > 1 and spec.is_moe and spec.moe.n_routed % ep:
        bad.append(f"n_routed={spec.moe.n_routed} (ep={ep})")
    if tp <= 1:
        return bad
    if spec.attention != AttentionKind.NONE and spec.n_h % tp:
        bad.append(f"n_h={spec.n_h}")
    if spec.attention not in (AttentionKind.NONE, AttentionKind.MLA) \
            and spec.n_kv % tp:
        bad.append(f"n_kv={spec.n_kv}")
    if spec.h_ff and spec.h_ff % tp:
        bad.append(f"h_ff={spec.h_ff}")
    if spec.is_moe and spec.moe.d_ff_expert % tp:
        bad.append(f"d_ff_expert={spec.moe.d_ff_expert}")
    if spec.vocab % tp:
        bad.append(f"vocab={spec.vocab}")
    return bad


def human_bytes(n: float) -> str:
    """GiB-based formatting matching the paper's 'GB' (actually GiB) usage."""
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if abs(n) >= div:
            return f"{n / div:.2f} {unit}"
    return f"{n:.0f} B"


def human_count(n: float) -> str:
    if abs(n) >= 1e9:
        return f"{n / 1e9:.2f}B"
    if abs(n) >= 1e6:
        return f"{n / 1e6:.2f}M"
    return f"{n:,.0f}"
