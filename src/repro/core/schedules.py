"""Pipeline-schedule tick emission (the schedule axis of the memory model).

PR 1 hard-coded one answer to "how many microbatches does a PP stage hold in
flight" — plain 1F1B's ``pp - stage``.  This module makes the schedule a
first-class object: a :class:`PipelineSchedule` emits, for every rank, a
sequence of ticks (forward/backward of which microbatch on which local layer
chunk), and everything downstream derives from that single tick stream:

* the analytic in-flight accounting (``core.activations.schedule_in_flight``
  and the time-resolved ``schedule_activation_bytes``),
* the runtime executor tables (``train.schedules.build_exec_tables``),
* the per-rank dry-run probes (``launch.dryrun --pp N --schedule ...``),
* the tick diagrams in ``docs/pipeline-schedules.md``.

Four schedules are implemented:

``1f1b``
    Plain GPipe-fill + 1F1B steady state (one layer chunk per rank).  Rank r
    holds ``min(M, pp - r)`` microbatches in flight — the paper's §6
    stage-dependent activation multiplier.

``interleaved``
    Megatron-style interleaved 1F1B over ``v`` virtual stages: the model is
    split into ``pp*v`` chunks and rank r owns chunks ``{r, pp+r, 2pp+r, …}``.
    Microbatches are processed in groups of ``pp`` per chunk (requires
    ``n_micro % pp == 0``); rank r's peak in-flight rises to
    ``min(M*v, (v-1)*pp + 2*(pp-r-1) + 1)`` *chunk* activations, each chunk
    carrying ~1/v of the rank's layers — the schedule trades bubble for a
    shallower, higher staircase (arXiv:2411.06465's schedule axis).

``dualpipe``
    DualPipe-style bidirectional schedule (arXiv:2505.09343): the model is
    split into ``pp`` stages but every rank holds TWO chunks — stage ``r``
    (forward direction) and stage ``pp-1-r`` (reverse direction) — and
    microbatches are fed from both ends.  This reproduces DualPipe's memory
    signature: 2× parameters and a near-flat in-flight profile
    ``min(⌈M/2⌉, pp-r) + min(⌊M/2⌋, r+1)`` ≈ ``pp+1`` on every rank.  We
    model the *alternating* variant (even ticks run the forward direction,
    odd ticks the reverse), which keeps the memory profile of DualPipe
    without its overlapped dual-stream compute.

``zb1p``
    ZB-H1 zero-bubble schedule (arXiv:2401.10241): the backward is split
    into B (input gradient, on the critical dx chain) and a third op kind
    ``W`` (weight gradient, off the critical path).  Each rank runs the
    1f1b F/B order unchanged plus a second queue of W ops, W(m) ordered
    after B(m); the greedy tick assigner gives F/B strict priority, so W
    ops land exactly in the ticks 1f1b would leave idle — the zero-bubble
    trick.  Activation residency is 1f1b's ``min(M, pp - r)`` (activations
    retire at B as before); what W defers is the *gradient-accumulation*
    work, priced by the memory model as one extra fp32 layer-grad buffer
    (``estimate_memory(schedule="zb1p")``).  With unit op costs the
    canonical bubble per rank drops from 1f1b's ``2(pp-1)`` idle slots to
    ``~(pp-1)`` (ZB-H1's (p-1)(F+B-W) vs (p-1)(F+B+W)).

Time model: canonical ticks are ONE op (F, B or W) per rank per tick, the unit
the in-flight literature uses; the runtime executor compresses this to one
F *and* one B per tick (see ``train.schedules``).  Both timelines are
emitted from the same per-rank op orders by :func:`assign_ticks`.

Everything here is pure Python/numpy (no jax) so ``core`` stays the lowest
layer of the package graph (see ``docs/architecture.md``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

SCHEDULES = ("1f1b", "interleaved", "dualpipe", "zb1p")


@dataclasses.dataclass(frozen=True)
class TickOp:
    """One scheduled operation: at tick ``t`` rank ``rank`` runs a forward
    (``op='F'``), input-gradient backward (``op='B'``) or — under zb1p —
    a deferred weight-gradient op (``op='W'``) of ``micro`` on its local
    layer chunk ``chunk`` (which holds global model chunk ``stage``)."""

    t: int
    rank: int
    op: str          # 'F' | 'B' | 'W'
    micro: int
    stage: int       # global model-chunk id, 0..n_stages-1 (traversal order)
    chunk: int       # local chunk index on the rank, 0..n_chunks-1


def schedule_placement(schedule: str, pp: int, n_chunks: int = 1
                       ) -> Tuple[Tuple[int, ...], ...]:
    """(pp, v) map: global model-chunk id held by (rank, local chunk).

    1f1b: v=1, rank r holds chunk r.  interleaved: v chunks, rank r holds
    ``c*pp + r``.  dualpipe: v=2 over ``pp`` model chunks, rank r holds
    ``(r, pp-1-r)`` — model chunks are *duplicated* across two ranks (the
    2×-parameter cost of DualPipe)."""
    v = norm_chunks(schedule, n_chunks)
    if schedule in ("1f1b", "zb1p"):
        return tuple((r,) for r in range(pp))
    if schedule == "interleaved":
        return tuple(tuple(c * pp + r for c in range(v)) for r in range(pp))
    if schedule == "dualpipe":
        return tuple((r, pp - 1 - r) for r in range(pp))
    raise ValueError(f"unknown schedule {schedule!r}; pick from {SCHEDULES}")


def n_model_chunks(schedule: str, pp: int, n_chunks: int = 1) -> int:
    """Number of (contiguous) model partitions the schedule runs over."""
    v = norm_chunks(schedule, n_chunks)
    return pp if schedule == "dualpipe" else pp * v


def norm_chunks(schedule: str, n_chunks: int) -> int:
    if schedule in ("1f1b", "zb1p"):
        if n_chunks != 1:
            raise ValueError(f"{schedule} uses n_chunks=1")
        return 1
    if schedule == "dualpipe":
        if n_chunks not in (1, 2):
            raise ValueError("dualpipe uses exactly 2 chunks per rank")
        return 2
    if schedule == "interleaved":
        if n_chunks < 2:
            raise ValueError("interleaved needs n_chunks >= 2 "
                             "(n_chunks=1 is plain 1f1b)")
        return n_chunks
    raise ValueError(f"unknown schedule {schedule!r}; pick from {SCHEDULES}")


# ---------------------------------------------------------------------------
# Per-rank op orders (the schedule *policy*, timing-free)
# ---------------------------------------------------------------------------

# An op is ('F'|'B', micro, stage).  Each rank runs a list of queues; ops
# within a queue execute strictly in order, queues are independent (dualpipe
# uses one queue per direction).  ``parity`` restricts a queue's ops to
# even (0) / odd (1) ticks.

@dataclasses.dataclass(frozen=True)
class _Queue:
    ops: Tuple[Tuple[str, int, int], ...]
    chunk: Dict[int, int]          # stage -> local chunk on this rank
    parity: Optional[int] = None


def _order_1f1b_pos(pp: int, pos: int, micros: Sequence[int],
                    stage: int) -> List[Tuple[str, int, int]]:
    """1F1B op order for a rank sitting at pipeline *position* ``pos``
    (0 = feeds first) of a ``pp``-deep pipeline, running model chunk
    ``stage`` for the given microbatch ids."""
    M = len(micros)
    warm = min(M, pp - 1 - pos)
    out: List[Tuple[str, int, int]] = []
    out += [("F", micros[m], stage) for m in range(warm)]
    for m in range(warm, M):
        out.append(("F", micros[m], stage))
        out.append(("B", micros[m - warm], stage))
    for m in range(M - warm, M):
        out.append(("B", micros[m], stage))
    return out


def _orders(schedule: str, pp: int, n_micro: int, v: int
            ) -> List[List[_Queue]]:
    """Per-rank queues of ops for the schedule."""
    if schedule == "1f1b":
        return [[_Queue(tuple(_order_1f1b_pos(pp, r, range(n_micro), r)),
                        {r: 0})]
                for r in range(pp)]

    if schedule == "zb1p":
        # ZB-H1: the F/B queue is exactly 1f1b's; a second queue holds the
        # deferred weight-gradient ops W_0..W_{M-1}.  The greedy assigner
        # visits queues in order, so F/B keep strict priority and W ops
        # fill the slots 1f1b leaves idle (the zero-bubble insight); the
        # per-op dependency W(m) -> after B(m) lives in assign_ticks.
        return [[_Queue(tuple(_order_1f1b_pos(pp, r, range(n_micro), r)),
                        {r: 0}),
                 _Queue(tuple(("W", m, r) for m in range(n_micro)), {r: 0})]
                for r in range(pp)]

    if schedule == "dualpipe":
        if pp < 2:
            raise ValueError("dualpipe needs pp >= 2")
        ma = (n_micro + 1) // 2
        a_micros = list(range(ma))                  # direction A: ranks 0..pp-1
        b_micros = list(range(ma, n_micro))         # direction B: ranks pp-1..0
        out = []
        for r in range(pp):
            qa = _Queue(tuple(_order_1f1b_pos(pp, r, a_micros, r)),
                        {r: 0}, parity=0)
            qb = _Queue(tuple(_order_1f1b_pos(pp, pp - 1 - r, b_micros,
                                              pp - 1 - r)),
                        {pp - 1 - r: 1}, parity=1)
            out.append([qa, qb])
        return out

    if schedule == "interleaved":
        if n_micro % pp:
            raise ValueError(
                f"interleaved schedule needs n_micro % pp == 0 "
                f"(got n_micro={n_micro}, pp={pp}) — Megatron's grouping")
        total = n_micro * v
        group = pp * v

        def fwd_op(k: int, rank: int) -> Tuple[str, int, int]:
            g, within = divmod(k, group)
            chunk = within // pp
            micro = g * pp + within % pp
            return ("F", micro, chunk * pp + rank)

        def bwd_op(k: int, rank: int) -> Tuple[str, int, int]:
            g, within = divmod(k, group)
            chunk = v - 1 - within // pp
            micro = g * pp + within % pp
            return ("B", micro, chunk * pp + rank)

        out = []
        for r in range(pp):
            warm = min(total, 2 * (pp - r - 1) + (v - 1) * pp)
            ops: List[Tuple[str, int, int]] = []
            ops += [fwd_op(k, r) for k in range(warm)]
            for k in range(warm, total):
                ops.append(fwd_op(k, r))
                ops.append(bwd_op(k - warm, r))
            ops += [bwd_op(k, r) for k in range(total - warm, total)]
            out.append([_Queue(tuple(ops), {c * pp + r: c for c in range(v)})])
        return out

    raise ValueError(f"unknown schedule {schedule!r}")


# ---------------------------------------------------------------------------
# Greedy in-order tick assignment
# ---------------------------------------------------------------------------

def assign_ticks(orders: List[List[_Queue]], n_stages: int, *,
                 fb_per_tick: bool) -> Dict[Tuple[str, int, int], int]:
    """Assign a tick to every op, respecting (i) in-queue order, (ii) data
    dependencies with one-tick transfer latency — F(m,g) strictly after
    F(m,g-1), B(m,g) strictly after B(m,g+1), W(m,g) strictly after
    B(m,g) — and (iii) rank capacity.

    ``fb_per_tick=False`` is the canonical timeline (one op per rank per
    tick; B(m, last) strictly after F(m, last); queue parity honoured —
    dualpipe's alternating directions).  ``fb_per_tick=True`` is the
    executor timeline: one F and one B per rank per tick (the last stage's
    backward may share its forward's tick — the 1F1B hand-off), queue
    parity ignored — the executor's tick body runs one forward *and* one
    backward slot, so a dualpipe rank packs F(direction A) with
    B(direction B) in the same tick, DualPipe's overlapped dual-stream
    shape — and W ops land only on ticks where the rank runs no F and no
    B: dedicated W-only ticks whose cond branch costs a weight-grad pass
    instead of a full F+B, the executor rendering of ZB-H1's
    fill-the-bubble-with-W."""
    assigned: Dict[Tuple[str, int, int], int] = {}
    ptrs = [[0] * len(qs) for qs in orders]
    remaining = sum(len(q.ops) for qs in orders for q in qs)
    t = 0
    limit = 8 * (remaining + n_stages + 8)

    def try_assign(r: int, qi: int, cap: Dict[str, int], t: int,
                   w_pass: bool) -> bool:
        q = orders[r][qi]
        if q.parity is not None and not fb_per_tick and t % 2 != q.parity:
            return False
        i = ptrs[r][qi]
        if i >= len(q.ops):
            return False
        kind, micro, stage = q.ops[i]
        if fb_per_tick and (kind == "W") != w_pass:
            return False
        ck = kind if fb_per_tick else "all"
        if cap[ck] <= 0:
            return False
        dep: Optional[Tuple[str, int, int]] = None
        same_tick_ok = False
        if kind == "F" and stage > 0:
            dep = ("F", micro, stage - 1)
        elif kind == "W":
            dep = ("B", micro, stage)
        elif kind == "B":
            if stage == n_stages - 1:
                dep = ("F", micro, stage)
                same_tick_ok = fb_per_tick
            else:
                dep = ("B", micro, stage + 1)
        if dep is not None:
            td = assigned.get(dep)
            if td is None or not (td < t or (same_tick_ok and td <= t)):
                return False
        assigned[(kind, micro, stage)] = t
        ptrs[r][qi] += 1
        cap[ck] -= 1
        return True

    while remaining:
        if t > limit:
            raise RuntimeError("schedule deadlocked (invalid op order)")
        for r, queues in enumerate(orders):
            cap = {"F": 1, "B": 1, "W": 1} if fb_per_tick else {"all": 1}
            progress = True
            while progress:
                progress = False
                for qi in range(len(queues)):
                    if try_assign(r, qi, cap, t, w_pass=False):
                        remaining -= 1
                        progress = True
            if fb_per_tick and cap["F"] == 1 and cap["B"] == 1:
                # F/B queues are drained-or-blocked and assigned nothing
                # this tick: the rank-tick is idle, so a W op may fill it
                # (a W never shares a tick with the rank's own F or B; the
                # F/B pass cannot re-enable afterwards — every cross-op
                # dependency is strict-previous-tick except the last
                # stage's F->B hand-off, which needs the F this pass
                # did not assign).
                for qi in range(len(queues)):
                    if try_assign(r, qi, cap, t, w_pass=True):
                        remaining -= 1
        t += 1
    return assigned


# ---------------------------------------------------------------------------
# The schedule object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """A fully-timed pipeline schedule: canonical per-rank tick stream plus
    the placement both the runtime and the memory model consume."""

    name: str
    pp: int
    n_micro: int
    n_chunks: int                                  # v, local chunks per rank
    placement: Tuple[Tuple[int, ...], ...]         # (pp, v) -> model chunk id
    ticks: Tuple[TickOp, ...]                      # canonical, sorted by t

    @property
    def n_stages(self) -> int:
        return n_model_chunks(self.name, self.pp, self.n_chunks)

    @property
    def n_ticks(self) -> int:
        return self.ticks[-1].t + 1 if self.ticks else 0

    def owner(self, stage: int, micro: int) -> Tuple[int, int]:
        """(rank, local chunk) executing model chunk ``stage`` for ``micro``
        (direction-dependent under dualpipe)."""
        if self.name == "dualpipe" and micro >= (self.n_micro + 1) // 2:
            return self.pp - 1 - stage, 1
        if self.name == "dualpipe":
            return stage, 0
        return stage % self.pp, stage // self.pp

    def rank_ticks(self, rank: int) -> List[TickOp]:
        return [op for op in self.ticks if op.rank == rank]

    def in_flight_series(self) -> np.ndarray:
        """(pp, v, T) int: microbatches forwarded-but-not-yet-retired on each
        (rank, chunk) at every tick — the activation-residency time series.
        A microbatch occupies its chunk from its forward tick through its
        backward tick inclusive (the backward recomputes from the stored
        boundary input, so the input stays resident until then)."""
        return _in_flight_series(self)

    def peak_in_flight(self) -> np.ndarray:
        """(pp, v) int: per-chunk peak in-flight microbatches."""
        return self.in_flight_series().max(axis=2)

    def rank_peak_in_flight(self, rank: int) -> int:
        """Peak simultaneous in-flight chunk-activations on ``rank``: the
        max of the summed per-chunk series.  The chunks need not peak at
        the same tick, so this can be strictly below the sum of per-chunk
        maxima — do not 'simplify' to ``peak_in_flight()[rank].sum()``."""
        return int(self.in_flight_series()[rank].sum(axis=0).max())

    def peak_profile(self, rank: int, weights: Optional[Sequence[float]]
                     = None) -> Tuple[float, Tuple[int, ...]]:
        """(peak, per-chunk counts at the peak tick) of the weighted
        in-flight series Σ_c w_c · k_c(t).  ``weights`` defaults to 1 per
        chunk (chunk-units); pass per-chunk activation bytes to get the
        byte-exact residency peak the memory model reports."""
        series = self.in_flight_series()[rank]
        w = np.ones(self.n_chunks) if weights is None \
            else np.asarray(list(weights), np.float64)
        total = (series * w[:, None]).sum(axis=0)
        t_star = int(total.argmax())
        return float(total[t_star]), tuple(int(x) for x in series[:, t_star])

    def check(self) -> None:
        """Raise if the tick stream violates the schedule invariants (every
        micro forwarded/backwarded — and, under zb1p, weight-gradded —
        exactly once per model chunk, backward after forward, W after its
        backward, dependencies with 1-tick latency, rank capacity)."""
        G, M = self.n_stages, self.n_micro
        f: Dict[Tuple[int, int], TickOp] = {}
        b: Dict[Tuple[int, int], TickOp] = {}
        w: Dict[Tuple[int, int], TickOp] = {}
        per_slot: Dict[Tuple[int, int], int] = {}
        for op in self.ticks:
            d = {"F": f, "B": b, "W": w}[op.op]
            key = (op.micro, op.stage)
            assert key not in d, f"duplicate {op}"
            d[key] = op
            k = (op.t, op.rank)
            per_slot[k] = per_slot.get(k, 0) + 1
            assert per_slot[k] == 1, f"rank capacity violated at {op}"
            r, c = self.owner(op.stage, op.micro)
            assert (op.rank, op.chunk) == (r, c), f"misplaced {op}"
        assert len(f) == G * M and len(b) == G * M, \
            f"expected {G * M} F and B ops, got {len(f)}/{len(b)}"
        if self.name == "zb1p":
            assert len(w) == G * M, f"expected {G * M} W ops, got {len(w)}"
        else:
            assert not w, f"{self.name} emitted W ops"
        for (m, g), op in f.items():
            if g > 0:
                assert f[(m, g - 1)].t < op.t, f"F dep violated at {op}"
        for (m, g), op in b.items():
            assert f[(m, g)].t <= op.t, f"B before F at {op}"
            if g < G - 1:
                assert b[(m, g + 1)].t < op.t, f"B dep violated at {op}"
        for (m, g), op in w.items():
            assert b[(m, g)].t < op.t, f"W before B at {op}"


@functools.lru_cache(maxsize=512)
def _in_flight_series(sched: "PipelineSchedule") -> np.ndarray:
    T = sched.n_ticks
    out = np.zeros((sched.pp, sched.n_chunks, T), np.int64)
    fwd: Dict[Tuple[int, int], int] = {}
    for op in sched.ticks:
        if op.op == "F":
            fwd[(op.micro, op.stage)] = op.t
    for op in sched.ticks:
        if op.op == "B":
            out[op.rank, op.chunk, fwd[(op.micro, op.stage)]:op.t + 1] += 1
    out.setflags(write=False)
    return out


@functools.lru_cache(maxsize=512)
def make_schedule(name: str, pp: int, n_micro: int,
                  n_chunks: int = 1) -> PipelineSchedule:
    """Build the canonical tick stream for ``name`` ∈ {1f1b, interleaved,
    dualpipe, zb1p}.  ``n_chunks`` is the virtual-stage count per rank
    (forced to 1 for 1f1b/zb1p and 2 for dualpipe; >= 2 for
    interleaved)."""
    v = norm_chunks(name, n_chunks)
    if pp < 1 or (name not in ("1f1b", "zb1p") and pp < 2):
        raise ValueError(f"{name} needs pp >= 2 (got {pp})")
    if n_micro < 1:
        raise ValueError("n_micro must be >= 1")
    placement = schedule_placement(name, pp, v)
    G = n_model_chunks(name, pp, v)
    orders = _orders(name, pp, n_micro, v)
    times = assign_ticks(orders, G, fb_per_tick=False)
    ticks = []
    for r, queues in enumerate(orders):
        for q in queues:
            for kind, micro, stage in q.ops:
                ticks.append(TickOp(t=times[(kind, micro, stage)], rank=r,
                                    op=kind, micro=micro, stage=stage,
                                    chunk=q.chunk[stage]))
    ticks.sort(key=lambda op: (op.t, op.rank, op.op))
    sched = PipelineSchedule(name=name, pp=pp, n_micro=n_micro, n_chunks=v,
                             placement=placement, ticks=tuple(ticks))
    return sched


def exec_tick_times(sched: PipelineSchedule
                    ) -> Dict[Tuple[str, int, int], int]:
    """Executor-timeline tick of every op (one F and one B per rank per
    tick; under zb1p, W ops on dedicated F/B-free ticks): the timing
    ``train.schedules.build_exec_tables`` compiles into the shard_map
    executor's static tables."""
    orders = _orders(sched.name, sched.pp, sched.n_micro, sched.n_chunks)
    return assign_ticks(orders, sched.n_stages, fb_per_tick=True)


@functools.lru_cache(maxsize=512)
def zb_pending_peak(pp: int, n_micro: int) -> Tuple[int, ...]:
    """Per-rank peak count of zb1p microbatches sitting between their B
    tick and their W tick on the executor timeline — the depth of the
    executor's pending-dW stash ring, and therefore what the memory model
    must price for ``schedule="zb1p"`` (one fp32 copy of the rank's
    per-layer grads per pending microbatch; see ``train.pipeline_loop``).
    jax-free: derived from ``exec_tick_times`` like every other executor
    bound."""
    sched = make_schedule("zb1p", pp, n_micro)
    times = exec_tick_times(sched)
    out = []
    for r in range(pp):
        T = max(times.values()) + 1
        load = np.zeros(T + 1, np.int64)
        for m in range(n_micro):
            load[times[("B", m, r)]:times[("W", m, r)]] += 1
        out.append(int(load.max()) if n_micro else 0)
    return tuple(out)
