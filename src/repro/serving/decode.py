"""Serving: batched single-token decode over a KV/latent/SSM cache.

``make_serve_step`` builds the jit-able step the decode-shape dry-runs
lower: one new token per sequence against a cache of ``cache_len`` tokens.
``serve_requests`` is a small batched-request driver (greedy or sampled)
used by the serving example and integration tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    eos_id: Optional[int] = None


def make_serve_step(model: Model) -> Callable[[PyTree, PyTree, jnp.ndarray],
                                              Tuple[jnp.ndarray, PyTree]]:
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


def prefill(model: Model, params: PyTree, cache: PyTree,
            prompt: jnp.ndarray) -> Tuple[PyTree, jnp.ndarray]:
    """Sequential prefill through decode_step (token-by-token; simple and
    cache-layout-exact).  prompt: (b, s)."""
    step = jax.jit(model.decode_step)
    logits = None
    for i in range(prompt.shape[1]):
        logits, cache = step(params, cache, prompt[:, i:i + 1])
    return cache, logits


def serve_requests(model: Model, params: PyTree, prompts: jnp.ndarray,
                   cfg: ServeConfig, cache_len: int,
                   enc_out: Optional[jnp.ndarray] = None,
                   rng: Optional[jax.Array] = None) -> jnp.ndarray:
    """Greedy/sampled continuation for a batch of prompts: (b, s) -> (b, n)."""
    b = prompts.shape[0]
    cache = model.init_cache(b, cache_len, enc_out=enc_out)
    cache, logits = prefill(model, params, cache, prompts)
    step = jax.jit(model.decode_step)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = []
    tok = None
    for i in range(cfg.max_new_tokens):
        if tok is None:
            lg = logits
        else:
            lg, cache = step(params, cache, tok)
        lg = lg[:, -1].astype(jnp.float32)
        if cfg.temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, lg / cfg.temperature)[:, None]
        else:
            tok = lg.argmax(-1)[:, None]
        tok = tok.astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
