from .decode import ServeConfig, make_serve_step, serve_requests

__all__ = ["ServeConfig", "make_serve_step", "serve_requests"]
