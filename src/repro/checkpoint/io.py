"""Checkpointing: flat-key npz snapshots of arbitrary pytrees.

Layout: <dir>/step_<n>/state.npz + manifest.json (treedef + dtypes).  On a
real multi-host pod each host writes its addressable shards
(``process_index`` suffix); in this single-process environment that
degenerates to one file, but the API keeps the shard dimension explicit.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "::"


def _key(path) -> str:
    return _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def _gather_full(leaf):
    """Assemble a device-sharded jax.Array (e.g. ZeRO-sharded state) into
    one host copy: jit-identity with a fully-replicated out sharding — the
    all-gather runs on device, so leaves whose shards live across the DP
    group (``os+g+params`` working params, sharded optimizer state)
    checkpoint without a crash instead of tripping ``np.asarray`` on a
    non-fully-addressable array."""
    from jax.sharding import NamedSharding, PartitionSpec
    sharding = getattr(leaf, "sharding", None)
    mesh = getattr(sharding, "mesh", None)
    if mesh is None:
        return np.asarray(jax.device_get(leaf))
    out = jax.jit(lambda a: a,
                  out_shardings=NamedSharding(mesh, PartitionSpec()))(leaf)
    return np.asarray(jax.device_get(out))


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out, gathered = {}, {}
    for path, leaf in flat:
        key = _key(path)
        sharded = (isinstance(leaf, jax.Array)
                   and not getattr(leaf, "is_fully_replicated", True))
        if sharded:
            out[key] = _gather_full(leaf)
        else:
            out[key] = np.asarray(leaf)
        gathered[key] = bool(sharded)
    return out, treedef, gathered


def save(directory: str, step: int, tree: PyTree) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _, gathered = _flatten(tree)
    shard = jax.process_index()
    path = os.path.join(d, f"state_{shard:03d}.npz")
    # npz can't hold ml_dtypes (bf16 etc.) — store them as a uint16 view;
    # the manifest records the true dtype for restore.
    storable = {k: (v.view(np.uint16) if v.dtype.kind == "V" or
                    v.dtype.name == "bfloat16" else v)
                for k, v in flat.items()}
    np.savez(path, **storable)
    # "gathered" notes leaves that were device-sharded at save time and
    # written as the assembled full array (ZeRO save-on-gather); restore
    # re-shards them onto the target tree's sharding.
    manifest = {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                    "gathered": gathered[k]}
                for k, v in flat.items()}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for n in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates shapes/dtypes).
    Leaves whose ``like`` counterpart is a device-sharded jax.Array are
    ``device_put`` back onto that sharding, so a ZeRO-sharded TrainState
    round-trips to its sharded layout (each device re-adopts its slice of
    the gathered full array the manifest marked ``gathered``)."""
    d = os.path.join(directory, f"step_{step:08d}")
    shard = jax.process_index()
    data = np.load(os.path.join(d, f"state_{shard:03d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, ref_leaf in flat:
        key = _key(path)
        arr = data[key]
        ref_dtype = jnp.asarray(ref_leaf).dtype if not hasattr(
            ref_leaf, "dtype") else ref_leaf.dtype
        assert arr.shape == tuple(np.shape(ref_leaf)), \
            (key, arr.shape, np.shape(ref_leaf))
        if ref_dtype == jnp.bfloat16 and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        sharding = getattr(ref_leaf, "sharding", None)
        if isinstance(ref_leaf, jax.Array) and sharding is not None:
            leaves.append(jax.device_put(
                jnp.asarray(arr, dtype=ref_dtype), sharding))
        else:
            leaves.append(jnp.asarray(arr, dtype=ref_dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
