"""Checkpointing: flat-key npz snapshots of arbitrary pytrees.

Layout: <dir>/step_<n>/state.npz + manifest.json (treedef + dtypes).  On a
real multi-host pod each host writes its addressable shards
(``process_index`` suffix); in this single-process environment that
degenerates to one file, but the API keeps the shard dimension explicit.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(directory: str, step: int, tree: PyTree) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat, _ = _flatten(tree)
    shard = jax.process_index()
    path = os.path.join(d, f"state_{shard:03d}.npz")
    # npz can't hold ml_dtypes (bf16 etc.) — store them as a uint16 view;
    # the manifest records the true dtype for restore.
    storable = {k: (v.view(np.uint16) if v.dtype.kind == "V" or
                    v.dtype.name == "bfloat16" else v)
                for k, v in flat.items()}
    np.savez(path, **storable)
    manifest = {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                for k, v in flat.items()}
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for n in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", n))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates shapes/dtypes)."""
    d = os.path.join(directory, f"step_{step:08d}")
    shard = jax.process_index()
    data = np.load(os.path.join(d, f"state_{shard:03d}.npz"))
    flat, treedef = _flatten(like)
    leaves = []
    for key, ref_leaf in flat.items():
        arr = data[key]
        assert arr.shape == ref_leaf.shape, (key, arr.shape, ref_leaf.shape)
        if ref_leaf.dtype.name == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(jnp.asarray(arr, dtype=ref_leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
