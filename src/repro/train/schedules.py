"""Pluggable pipeline schedules: the runtime-facing API.

The schedule *abstraction* — per-rank tick emission (which microbatch, which
virtual-stage chunk, forward or backward, where the boundary tensors travel)
— lives in :mod:`repro.core.schedules` so the analytic memory model can
consume it without importing the runtime; this module re-exports it and adds
the one runtime-specific piece: :func:`build_exec_tables`, which compiles a
:class:`~repro.core.schedules.PipelineSchedule` into the static numpy tables
the SPMD executor (``train.pipeline_loop``) indexes with
``lax.axis_index('pipe')`` inside its tick scan.

Executor timeline vs canonical timeline
---------------------------------------

Canonical ticks (``PipelineSchedule.ticks``) are one op per rank per tick —
the unit the in-flight accounting uses.  The executor instead pairs one
cond-gated forward with one cond-gated backward per tick (plus, for
schedules that split the backward, a dedicated cond-gated W tick that never
shares a rank-tick with the rank's own F or B), so ``build_exec_tables``
re-times the same per-rank op order under that capacity via
``core.schedules.exec_tick_times`` and then derives:

* per-tick forward/backward tables: is the rank active, which microbatch,
  which local chunk, which buffer slot;
* boundary routing: whether the rank's forward output / input-gradient
  travels down-ring (rank r → r+1, the 1f1b/interleaved direction; also
  interleaved's wraparound pp-1 → 0 between virtual stages) or up-ring
  (dualpipe's reverse direction), and where the *receiving* rank must store
  the payload;
* buffer slot assignments: boundary inputs (and arriving gradients) are
  kept in per-chunk slot rings; slots are assigned by greedy interval
  colouring over each value's residency window, so the ring size **is** the
  executor's true in-flight bound for that (rank, chunk) — the quantity the
  schedule-aware memory model estimates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.schedules import (SCHEDULES, PipelineSchedule, TickOp,
                                  exec_tick_times, make_schedule,
                                  n_model_chunks, schedule_placement)

__all__ = ["SCHEDULES", "PipelineSchedule", "TickOp", "ExecTables",
           "build_exec_tables", "make_schedule", "n_model_chunks",
           "schedule_placement"]


@dataclasses.dataclass(frozen=True)
class ExecTables:
    """Static (T, pp) executor tables; ``*_idx`` entries are flat buffer
    indices ``chunk * slots_per_chunk + slot``.  Inactive entries hold 0 and
    are masked by the matching ``*_act`` table."""

    schedule: str
    pp: int
    n_chunks: int
    n_micro: int
    n_stages: int
    T: int
    x_slots: int            # boundary-input slots per chunk
    g_slots: int            # gradient slots per chunk
    # forward compute
    f_act: np.ndarray
    f_micro: np.ndarray
    f_chunk: np.ndarray
    f_xidx: np.ndarray
    # backward compute
    b_act: np.ndarray
    b_micro: np.ndarray
    b_chunk: np.ndarray
    b_xidx: np.ndarray
    b_gidx: np.ndarray
    # sends (sender side, end of tick): does this rank's fwd out / grad out
    # travel down-ring (r -> r+1 mod pp) or up-ring (r -> r-1 mod pp)?
    fsend_down: np.ndarray
    fsend_up: np.ndarray
    bsend_down: np.ndarray
    bsend_up: np.ndarray
    # receives (receiver side, end of tick): store the arriving payload at
    # the flat buffer index
    rfd_act: np.ndarray     # fwd payload via down-ring
    rfd_idx: np.ndarray
    rfu_act: np.ndarray     # fwd payload via up-ring
    rfu_idx: np.ndarray
    rgd_act: np.ndarray     # grad payload via down-ring
    rgd_idx: np.ndarray
    rgu_act: np.ndarray     # grad payload via up-ring
    rgu_idx: np.ndarray
    # deferred weight-gradient application (zb1p's W ops; all-zero
    # otherwise): B runs the chunk vjp once (no slot checkpointing — the
    # split stashes grads instead of recomputing activations) and writes
    # the fp32 pending-dW into stash slot ``b_sidx``; at tick t rank r's W
    # op flushes stash slot ``w_sidx`` into the grad accumulator for
    # (``w_micro``, ``w_chunk``).  ``s_slots`` is the stash ring depth per
    # (rank, chunk) — the interval colouring of the B→W pendency windows,
    # whose peak is ``core.schedules.zb_pending_peak`` (what the memory
    # model prices; see train.pipeline_loop)
    w_act: np.ndarray = None
    w_micro: np.ndarray = None
    w_chunk: np.ndarray = None
    b_sidx: np.ndarray = None
    w_sidx: np.ndarray = None
    s_slots: int = 1


def _color_intervals(intervals: List[Tuple[int, int, int]]) -> Dict[int, int]:
    """Greedy interval colouring: micro -> slot, with [start, end) windows
    (a write landing exactly when the previous occupant is released may
    reuse its slot — the executor writes arrivals after the tick's reads)."""
    out: Dict[int, int] = {}
    free_at: List[int] = []
    for start, end, m in sorted(intervals):
        for s, f in enumerate(free_at):
            if f <= start:
                free_at[s] = end
                out[m] = s
                break
        else:
            out[m] = len(free_at)
            free_at.append(end)
    return out


def build_exec_tables(sched: PipelineSchedule) -> ExecTables:
    pp, v, G, M = sched.pp, sched.n_chunks, sched.n_stages, sched.n_micro
    times = exec_tick_times(sched)
    T = max(times.values()) + 1
    own = [[sched.owner(g, m) for g in range(G)] for m in range(M)]
    tF = {(m, g): times[("F", m, g)] for m in range(M) for g in range(G)}
    tB = {(m, g): times[("B", m, g)] for m in range(M) for g in range(G)}
    tW = {(m, g): times[("W", m, g)] for m in range(M) for g in range(G)
          if ("W", m, g) in times}

    # --- buffer slot assignment (per rank-chunk interval colouring) -------
    # A slot is held until its last reader, the B tick (zb1p's W op reads
    # the grad stash, not the x/g rings — B is still the rings' last
    # reader).  The stash gets its own colouring over the B→W pendency
    # windows; its per-(rank, chunk) peak is core.schedules.zb_pending_peak,
    # which is what the memory model prices for zb1p.
    xiv: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    giv: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    siv: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    for m in range(M):
        for g in range(G):
            r, c = own[m][g]
            t_rel = tB[(m, g)]                      # last read releases slot
            if g > 0:       # boundary input arrives when upstream F finishes
                xiv.setdefault((r, c), []).append(
                    (tF[(m, g - 1)], t_rel, m))
            if g < G - 1:   # cotangent arrives when downstream B finishes
                giv.setdefault((r, c), []).append(
                    (tB[(m, g + 1)], t_rel, m))
            if (m, g) in tW:    # pending-dW lives from its B to its W tick
                siv.setdefault((r, c), []).append(
                    (tB[(m, g)], tW[(m, g)], m))
    xslot = {rc: _color_intervals(iv) for rc, iv in xiv.items()}
    gslot = {rc: _color_intervals(iv) for rc, iv in giv.items()}
    sslot = {rc: _color_intervals(iv) for rc, iv in siv.items()}
    xs = max([max(sl.values()) + 1 for sl in xslot.values()] or [1])
    gs = max([max(sl.values()) + 1 for sl in gslot.values()] or [1])
    ss = max([max(sl.values()) + 1 for sl in sslot.values()] or [1])

    def z(dtype=np.int32):
        return np.zeros((T, pp), dtype)

    f_act, f_micro, f_chunk, f_xidx = z(np.float32), z(), z(), z()
    b_act, b_micro, b_chunk, b_xidx, b_gidx = z(np.float32), z(), z(), z(), z()
    fsd, fsu, bsd, bsu = z(np.float32), z(np.float32), z(np.float32), \
        z(np.float32)
    rfd_a, rfd_i, rfu_a, rfu_i = z(np.float32), z(), z(np.float32), z()
    rgd_a, rgd_i, rgu_a, rgu_i = z(np.float32), z(), z(np.float32), z()
    w_act, w_micro, w_chunk, b_si, w_si = \
        z(np.float32), z(), z(), z(), z()

    for m in range(M):
        for g in range(G):
            r, c = own[m][g]
            t = tF[(m, g)]
            f_act[t, r] = 1.0
            f_micro[t, r] = m
            f_chunk[t, r] = c
            f_xidx[t, r] = c * xs + (xslot[(r, c)][m] if g > 0 else 0)
            if g < G - 1:
                r2, c2 = own[m][g + 1]
                down = (r2 - r) % pp == 1
                (fsd if down else fsu)[t, r] = 1.0
                a, i = (rfd_a, rfd_i) if down else (rfu_a, rfu_i)
                a[t, r2] = 1.0
                i[t, r2] = c2 * xs + xslot[(r2, c2)][m]

            t = tB[(m, g)]
            b_act[t, r] = 1.0
            b_micro[t, r] = m
            b_chunk[t, r] = c
            b_xidx[t, r] = c * xs + (xslot[(r, c)][m] if g > 0 else 0)
            b_gidx[t, r] = c * gs + (gslot[(r, c)][m] if g < G - 1 else 0)
            if (m, g) in tW:
                b_si[t, r] = c * ss + sslot[(r, c)][m]
            if g > 0:
                r2, c2 = own[m][g - 1]
                down = (r2 - r) % pp == 1
                (bsd if down else bsu)[t, r] = 1.0
                a, i = (rgd_a, rgd_i) if down else (rgu_a, rgu_i)
                a[t, r2] = 1.0
                i[t, r2] = c2 * gs + gslot[(r2, c2)][m]

            if (m, g) in tW:
                t = tW[(m, g)]
                w_act[t, r] = 1.0
                w_micro[t, r] = m
                w_chunk[t, r] = c
                w_si[t, r] = c * ss + sslot[(r, c)][m]

    return ExecTables(
        schedule=sched.name, pp=pp, n_chunks=v, n_micro=M, n_stages=G, T=T,
        x_slots=xs, g_slots=gs,
        f_act=f_act, f_micro=f_micro, f_chunk=f_chunk, f_xidx=f_xidx,
        b_act=b_act, b_micro=b_micro, b_chunk=b_chunk, b_xidx=b_xidx,
        b_gidx=b_gidx,
        fsend_down=fsd, fsend_up=fsu, bsend_down=bsd, bsend_up=bsu,
        rfd_act=rfd_a, rfd_idx=rfd_i, rfu_act=rfu_a, rfu_idx=rfu_i,
        rgd_act=rgd_a, rgd_idx=rgd_i, rgu_act=rgu_a, rgu_idx=rgu_i,
        w_act=w_act, w_micro=w_micro, w_chunk=w_chunk,
        b_sidx=b_si, w_sidx=w_si, s_slots=ss)
