"""Training loop: micro-batched gradient accumulation (fp32 buffers, the
paper's Table-7 gradient dtype), AdamW update, metrics.

``make_train_step`` builds the jit-able step the dry-run lowers: the global
batch is split into ``n_micro`` micro-batches of size b (the paper's 'b'
knob), scanned with fp32 grad accumulation, then one optimizer update.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import (AdamWConfig, TrainState, adamw_update,
                               init_train_state)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1              # grad-accumulation steps per train step
    adamw: AdamWConfig = AdamWConfig()


def _split_micro(batch: Dict[str, jnp.ndarray], n_micro: int
                 ) -> Dict[str, jnp.ndarray]:
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} not divisible by n_micro {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model: Model, cfg: TrainConfig
                    ) -> Callable[[TrainState, Dict[str, jnp.ndarray]],
                                  Tuple[TrainState, Dict[str, jnp.ndarray]]]:
    def loss_fn(params, micro):
        return model.loss(params, micro)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        micro = _split_micro(batch, cfg.n_micro)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def accum(carry, mb):
            grads, loss_sum = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            grads = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grads, g)
            return (grads, loss_sum + loss), None

        (grads, loss_sum), _ = jax.lax.scan(
            accum, (zero_grads, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / cfg.n_micro, grads)
        new_state, opt_metrics = adamw_update(state, grads, cfg.adamw)
        metrics = {"loss": loss_sum / cfg.n_micro, **opt_metrics}
        return new_state, metrics

    return train_step


def train(model: Model, batches: Iterator[Dict[str, jnp.ndarray]],
          n_steps: int, cfg: Optional[TrainConfig] = None,
          rng: Optional[jax.Array] = None,
          log_every: int = 10,
          state: Optional[TrainState] = None,
          callback: Optional[Callable[[int, Dict], None]] = None
          ) -> Tuple[TrainState, list]:
    """Single-host convenience driver (examples/tests)."""
    cfg = cfg or TrainConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if state is None:
        params = model.init(rng)
        state = init_train_state(params)
    step_fn = jax.jit(make_train_step(model, cfg))
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        if i >= n_steps:
            break
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(i, m)
    return state, history
