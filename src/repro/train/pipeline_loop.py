"""Pipeline-parallel train step: GPipe fill + 1F1B steady state over the
``pipe`` mesh axis.

One SPMD program (``shard_map``): every device holds one stage's slice of the
stage-stacked parameters (``models.pipeline.stack_pipeline_params``) and runs
the same tick loop; stage identity is ``lax.axis_index('pipe')``.  A tick t
pairs one (masked) forward with one (masked) backward:

  forward  of microbatch  m_f = t - d             on stage d,
  backward of microbatch  m_b = t - 2(pp-1) + d   on stage d,

so microbatches fill the pipeline GPipe-style (stage d idles until t = d),
the last stage runs its first backward in the same tick as its first forward
(the 1F1B hand-off), and upstream stages drain afterwards.  Boundary
activations travel downstream and activation-gradients upstream via one
``lax.ppermute`` each per tick.  Total ticks T = n_micro + 2(pp-1).

Backward is *manual* (the tick loop is not differentiated): each stage keeps
a ring of its in-flight boundary inputs, recomputes its forward for the
microbatch being retired, and pulls gradients through ``jax.vjp`` with the
downstream cotangent — stage-granular recompute, the standard JAX pipeline
construction.  In-flight boundary inputs per stage are bounded by
min(n_micro, 2·pp-1) and decrease toward the last stage; the analytical
model's canonical 1F1B counts (``core.one_f1b_in_flight``: pp - stage) share
the same monotone shape, which is what the per-stage memory validation
checks.

Semantics match ``train.loop.make_train_step``: fp32 gradient accumulation
across microbatches, mean over n_micro, one AdamW update, loss metric
ce + 0.01·aux per microbatch.  ``TrainState`` keeps the pp=1 layout — grads
are unstacked back before the update — so optimizer, checkpointing and the
pp=1 path are untouched.

Scope: mesh axes ('pipe',) or ('pipe', 'data'); TP inside a stage is not
executed here (the per-stage dry-run programs cover TP via GSPMD).  MoE aux
uses the scatter dispatch and is pmean'd across data shards.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import embed_apply, rmsnorm
from repro.models.model import Model
from repro.models.pipeline import (check_pipeline_supported, partition,
                                   pipeline_stage_apply,
                                   stack_pipeline_params,
                                   unstack_pipeline_grads)
from repro.optim.adamw import TrainState, adamw_update
from repro.parallel.compat import shard_map
from repro.parallel.sharding import pipeline_stage_specs
from repro.train.loop import TrainConfig, _split_micro

PyTree = Any


def _ce_mask(mask: Optional[jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    targets_shape = (tokens.shape[0], tokens.shape[1] - 1)
    if mask is None:
        return jnp.ones(targets_shape, jnp.float32)
    m = mask[:, 1:] if mask.shape == tokens.shape else mask
    return m.astype(jnp.float32)


def _ce_sum(logits: jnp.ndarray, tokens: jnp.ndarray,
            mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Unnormalized token-CE sum over the local batch shard (fp32), the
    summand of Model.loss's masked mean."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * _ce_mask(mask, tokens))


def make_pipeline_train_step(model: Model, cfg: TrainConfig, mesh: Mesh):
    """Build the jit-able 1F1B step for ``mesh`` (axes ('pipe'[, 'data']));
    pp = mesh.shape['pipe'].  Same contract as ``make_train_step``."""
    spec, opts = model.spec, model.opts
    check_pipeline_supported(spec)
    if "pipe" not in mesh.axis_names:
        raise ValueError("pipeline step needs a 'pipe' mesh axis "
                         "(launch.mesh.make_production_mesh(pp=...))")
    if mesh.shape.get("model", 1) != 1:
        raise NotImplementedError(
            "1F1B executor runs TP=1 inside stages; per-stage TP memory is "
            "covered by the dry-run's stage programs")
    S = mesh.shape["pipe"]
    part = partition(spec, S)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    M = cfg.n_micro
    T = M + 2 * (S - 1)
    B = min(M, 2 * S - 1)                 # boundary-input ring (in-flight cap)
    gemma = spec.name.startswith("gemma")
    masks_all = jnp.asarray(part.mask)
    flags_all = jnp.asarray(part.moe_flag)

    def _psum(x, axes):
        return jax.lax.psum(x, axes) if axes else x

    def _run(stacked: PyTree, slot_masks: jnp.ndarray,
             slot_flags: jnp.ndarray, toks: jnp.ndarray,
             mmask: Optional[jnp.ndarray]):
        """shard_map body: returns (stage-stacked fp32 grads, loss_sum)."""
        d = jax.lax.axis_index("pipe")
        is_first, is_last = d == 0, d == S - 1
        p = jax.tree.map(lambda a: jnp.squeeze(a, 0), stacked)
        slot_mask, slot_flag = slot_masks[0], slot_flags[0]  # local stage row
        _, b_loc, s = toks.shape
        h = spec.h
        adt = p["embed"]["w"].dtype

        def stage_fn(p_, x_recv, tok, mm):
            """Uniform per-stage program: embed (selected on stage 0), this
            stage's union slots, head + local CE sum (meaningful on the last
            stage, zero-cotangent elsewhere)."""
            x0 = embed_apply(p_["embed"], tok, scale_by_dim=gemma, h=spec.h)
            x = jnp.where(is_first, x0, x_recv)
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b_loc, s))
            y, aux = pipeline_stage_apply(p_["layers"], spec, opts, x,
                                          positions, slot_mask, slot_flag)
            z = rmsnorm(p_["final_norm"], y, spec.norm_eps, gemma_style=gemma)
            w_out = p_["embed"]["w"].T if spec.tie_embeddings \
                else p_["head"]["w"]
            logits = z @ w_out
            return y, _ce_sum(logits, tok, mm), aux

        def micro_at(arr, m):
            return jax.lax.dynamic_index_in_dim(arr, m, 0, keepdims=False)

        def count_g(tok, mm):
            return _psum(jnp.sum(_ce_mask(mm, tok)), data_axes)

        def tick(carry, t):
            x_recv, dy, saved, g, loss, aux_acc = carry

            # -- forward: microbatch m_f enters/advances ------------------
            m_f = t - d
            act_f = (m_f >= 0) & (m_f < M)
            mf = jnp.clip(m_f, 0, M - 1)
            tok_f = micro_at(toks, mf)
            mm_f = None if mmask is None else micro_at(mmask, mf)
            y, ce_sum, aux_f = stage_fn(p, x_recv, tok_f, mm_f)
            ce_m = _psum(ce_sum, data_axes) / jnp.maximum(
                count_g(tok_f, mm_f), 1.0)
            fmask = act_f.astype(jnp.float32)
            loss = loss + fmask * jnp.where(is_last, ce_m, 0.0)
            aux_acc = aux_acc + fmask * aux_f
            saved = jnp.where(
                act_f,
                jax.lax.dynamic_update_index_in_dim(saved, x_recv, mf % B, 0),
                saved)

            # -- backward: microbatch m_b retires (stage-recompute vjp) ---
            m_b = t - 2 * (S - 1) + d
            act_b = (m_b >= 0) & (m_b < M)
            mb = jnp.clip(m_b, 0, M - 1)
            tok_b = micro_at(toks, mb)
            mm_b = None if mmask is None else micro_at(mmask, mb)
            x_saved = micro_at(saved, mb % B)
            _, vjp_fn = jax.vjp(lambda p_, x_: stage_fn(p_, x_, tok_b, mm_b),
                                p, x_saved)
            bmask = act_b.astype(jnp.float32)
            dy_cot = jnp.where(act_b & (~is_last), dy,
                               jnp.zeros((), dy.dtype))
            dce = bmask * jnp.where(is_last, 1.0, 0.0) / jnp.maximum(
                count_g(tok_b, mm_b), 1.0)
            # aux is a per-data-shard token mean; the loss term is its pmean,
            # so each shard's cotangent carries 1/data_size (the grads are
            # psummed over the data axes below)
            daux = 0.01 * bmask / data_size
            dp, dx = vjp_fn((dy_cot, dce, daux))
            g = jax.tree.map(lambda acc, gg: acc + gg.astype(jnp.float32),
                             g, dp)

            # -- boundary exchange ---------------------------------------
            x_next = jax.lax.ppermute(y, "pipe",
                                      [(i, i + 1) for i in range(S - 1)])
            dy_next = jax.lax.ppermute(dx, "pipe",
                                       [(i, i - 1) for i in range(1, S)])
            return (x_next, dy_next, saved, g, loss, aux_acc), None

        init = (jnp.zeros((b_loc, s, h), adt),
                jnp.zeros((b_loc, s, h), adt),
                jnp.zeros((B, b_loc, s, h), adt),
                jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p),
                jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32))
        (_, _, _, g, loss, aux_acc), _ = jax.lax.scan(
            tick, init, jnp.arange(T))

        g = jax.tree.map(lambda a: _psum(a, data_axes)[None], g)
        aux_acc = jax.lax.pmean(aux_acc, data_axes) if data_axes else aux_acc
        loss_sum = jax.lax.psum(loss + 0.01 * aux_acc, "pipe")
        return g, loss_sum

    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def step(state: TrainState, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        micro = _split_micro(batch, M)
        toks = micro["tokens"]
        if toks.shape[1] % data_size:
            raise ValueError(
                f"micro-batch size {toks.shape[1]} must divide the data axes "
                f"(size {data_size})")
        stacked = stack_pipeline_params(state.params, spec, S)
        stage_specs = pipeline_stage_specs(stacked, mesh)
        dspec = tuple(data_axes) if data_axes else None
        margs = (toks,)
        mspecs = (P(None, dspec, None),)
        if "mask" in micro:
            margs += (micro["mask"],)
            mspecs += (P(None, dspec, *(None,) * (micro["mask"].ndim - 2)),)

        def inner(stacked_l, masks_l, flags_l, toks_l, *rest):
            return _run(stacked_l, masks_l, flags_l, toks_l,
                        rest[0] if rest else None)

        g_st, loss_sum = shard_map(
            inner, mesh=mesh,
            in_specs=(stage_specs, P("pipe", None), P("pipe", None))
            + mspecs,
            out_specs=(stage_specs, P()),
        )(stacked, masks_all, flags_all, *margs)
        grads = unstack_pipeline_grads(g_st, state.params, spec, S)
        grads = jax.tree.map(lambda a: a / M, grads)
        new_state, opt_metrics = adamw_update(state, grads, cfg.adamw)
        metrics = {"loss": loss_sum / M, **opt_metrics}
        return new_state, metrics

    return step
