"""Pipeline-parallel train step driven by a pluggable schedule.

``make_pipeline_train_step(model, cfg, mesh, schedule=..., n_chunks=...)``
builds one jit-able step that runs any of the four schedules in
``train.schedules`` / ``core.schedules`` — plain ``1f1b`` (the default,
PR 1's GPipe-fill + 1F1B steady state), Megatron-style ``interleaved``
virtual stages, the ``dualpipe`` bidirectional schedule, or the ``zb1p``
zero-bubble schedule (ZB-H1: the backward runs once at B, the per-layer
weight grads park in an fp32 pending stash and are applied on a dedicated
W tick — see the overlap-engine notes below) — over the ``pipe`` mesh
axis.  Arguments:

* ``model``: a ``models.build_model`` Model (decoder-only dense/MoE
  families; see ``models.pipeline.check_pipeline_supported``),
* ``cfg``: ``TrainConfig`` — ``cfg.n_micro`` microbatches per step
  (``interleaved`` requires ``n_micro % pp == 0``),
* ``mesh``: any of ``('pipe',)``, ``('pipe', 'data')`` or the full 3D
  ``('pipe', 'data', 'model')`` (``launch.mesh.make_production_mesh(pp=…)``);
  pp = mesh.shape['pipe'], tp = mesh.shape.get('model', 1),
* ``schedule``/``n_chunks``: schedule name and virtual stages per rank,
* ``zero``: ``ZeROStage`` — shard optimizer state (``os``), + gradients
  (``os+g``) across each stage's DP group (the 'data'(+'pod') axes).

One SPMD program (``shard_map``, fully manual over every mesh axis): every
device holds one rank's slice of the chunk-stacked parameters
(``models.pipeline.stack_pipeline_params``, leaves
``(pp, n_chunks, l_max, ...)``) — and, with a 'model' axis, its 1/tp TP
shard of them (``parallel.sharding.pipeline_stage_specs``: Megatron
head/column splits for attention and MLPs, expert-ff (ETP) splits for MoE,
vocab rows/columns for embedding/head) — and runs the same tick loop; rank
identity is ``lax.axis_index('pipe')``.  What happens at tick t — forward
or backward of which microbatch on which local chunk, and where boundary
tensors travel — is read from the schedule's static tables
(``train.schedules.build_exec_tables``), which re-time the canonical tick
stream under the executor's one-forward + one-backward (+ one W, for
schedules that split the backward) per tick capacity.

The tick body is an *overlap engine*, not a masked replay:

* **cond-gated compute** — each of the tick's F / B / W programs runs
  under ``lax.cond`` on its activity table, so a rank whose table row is
  idle (warmup, cooldown, drained) executes a no-op branch that just
  threads the carried buffers through: idle ticks cost ~0 instead of a
  full masked forward+backward.  The gate predicate depends only on the
  'pipe' rank, so it is uniform across 'data'/'model' and the collectives
  *inside* the branches (data psums, TP/SP operators, EP all-to-all)
  remain deadlock-free; the 'pipe' ppermutes — whose peers have
  *different* predicates — stay outside the conds.
* **true W-only ticks** — for ``zb1p`` the backward is the ZB-H1 split:
  B runs the fused chunk vjp once *without* slot checkpointing (the split
  stashes grads instead of recomputing activations, so the replay the
  checkpoint policy would pay is gone), retires dx and the shared
  embed/head/norm grads, and writes the per-layer fp32 pending-dW into a
  scan-carried stash slot (``b_sidx``); the dedicated W tick is a pure
  stash → accumulator flush (``w_sidx``) — cooldown fills with cheap W
  work exactly as ZB-H1 intends.  The stash ring depth is the interval
  colouring of the B→W pendency, whose peak
  ``core.schedules.zb_pending_peak`` the memory model prices.
* **async boundary comms** — each tick issues its forward-boundary
  ppermutes right after F and consumes them only after B/W (the transfer
  overlaps the backward), and the input-gradient computed by B rides the
  scan carry so its ppermute is issued at the *top of the next tick*,
  overlapping that tick's forward (the grad-receive tables are shifted
  one tick to match).  Inside the MoE chunk the EP all-to-all is likewise
  issued before — and consumed after — the shared expert's independent
  compute (``models.moe._moe_forward_ep``), the DualPipe dual-stream
  shape.

Boundary activations and activation-gradients move via
``lax.ppermute`` down-ring and (for dualpipe's reverse direction and
interleaved's virtual-stage wraparound) up-ring, landing in per-chunk slot
rings whose statically-coloured size is the executor's true in-flight bound
— the quantity ``core.schedule_in_flight`` models analytically.

Backward is *manual* (the tick loop is not differentiated): each rank keeps
its in-flight boundary inputs, recomputes the retiring chunk's forward, and
pulls gradients through ``jax.vjp`` with the downstream cotangent —
chunk-granular recompute, the standard JAX pipeline construction.  Under
``dualpipe`` every model chunk lives on two ranks (the schedule's 2×
parameter cost); ``unstack_pipeline_grads`` sums both copies' gradients.

Tensor parallelism runs *inside* each rank's chunk forward/backward.
Nested GSPMD is not viable on the targeted jax versions (the partitioner
rejects ``ppermute`` under a partially-auto ``shard_map``), so TP is the
explicit Megatron construction: the chunk forward sees the TP-local spec
(``parallel.tp.tp_local_spec`` — n_h/n_kv/h_ff/d_ff_expert divided by tp)
and the paired f/g operators of ``parallel.tp`` bracket every sharded
region (``copy_to_tp``: identity-fwd/psum-bwd where the replicated
residual enters sharded compute; ``reduce_from_tp``: psum-fwd/identity-bwd
where partial outputs leave it).  Embedding and head are vocab-parallel
(``embed_tp`` masked-gather rows; ``ce_sum_tp`` distributed log-sum-exp
over column-sharded logits).  With f/g at every boundary, every cotangent
in the manual backward is the exact global cotangent — so local weight
gradients (sharded and replicated leaves alike) are exact with no extra
model-axis reduction, and the boundary ``ppermute`` payloads stay
replicated across 'model', composing with TP untouched.

``sp=True`` adds Megatron-style sequence parallelism on the same 'model'
axis (degree = tp, the paper's SP column): the residual stream, norm
inputs and boundary activations live *seq-sharded* — (b, s/tp, h) per
device, the Table-10 ``/sp`` divisor made executor-real — and the f/g
pair is swapped for ğ and its dual (``gather_from_sp``: all-gather-fwd /
reduce-scatter-bwd on entry to every TP region; ``scatter_to_sp``:
reduce-scatter-fwd / all-gather-bwd on exit).  The embedding
reduce-scatters straight into the seq shard, the head gathers the
final-norm output before the column-sharded logits, MLA's replicated
latent towers consume the gathered view (latents stay full-length — the
paper's undivided 2bs(d_cq+d_c) terms), and MoE routes/dispatches each
shard's own token chunk with the dispatch buffer gathered over its
capacity dim (``models.moe.moe_forward(sp_axis=...)``).  Boundary
``ppermute`` payloads and the in-flight slot rings shrink to 1/tp of
their bytes.  One asymmetry is inherited from Megatron: weights consumed
*inside* the seq-sharded region — the ln1/ln2/final-norm scales and the
MoE router — see only their shard's tokens (their local grads are
seq-partial), and MLA's replicated latent towers run *without*
``copy_to_tp`` under SP (the entry ğ's reduce-scatter backward performs
the cross-shard sum; a psum-bwd on the latents would double-count), so
their weight grads are head-partial; the executor completes exactly
those leaves with a single ``psum`` over 'model' after the tick loop
(every other leaf stays exact-local as before).

``zero`` applies DeepSpeed-style state partitioning at the executor level
(previously dry-run-only): {master, m, v} — and for ``os+g`` the fp32
gradient buffers — carry ``with_sharding_constraint`` s from
``parallel.sharding.state_shardings``/``grad_shardings``, which extend
each leaf's §3 TP spec with the data(+pod) axes; since PP groups are
data-major, those axes are exactly the per-stage DP group, so each DP
shard holds 1/dp of its stage's optimizer bytes and XLA reduce-scatters
grads into the sharded AdamW update.

``os+g+params`` (ZeRO-3) goes one further: the bf16 *working* params
themselves live DP-sharded (``parallel.sharding.zero3_stage_specs``
extends the stacked per-stage specs with the data(+pod) axes on each
leaf's first shardable weight dim) and every F/B tick *gathers on use* —
``parallel.tp.gather_params``, the DP analogue of SP's ğ applied to
weights: forward all-gathers the tick's chunk slice (a transient the
memory model prices as ``gather_transient``), backward reduce-scatters
the weight cotangent, which sums the cross-DP grad contributions and
re-shards onto the owner in one collective.  The post-loop data psum is
skipped for exactly the gathered leaves (their grads arrive summed and
shard-sized); tiny leaves with no DP-divisible dim keep the replicated
layout and the psum path (DeepSpeed's small-tensor fallback).  The
gather/scatter live *inside* the cond-gated F/B branches — safe because
the gate predicate depends only on the 'pipe' rank, so it is uniform
across the 'data'(+'pod') axes the collectives run over.

Semantics match ``train.loop.make_train_step``: fp32 gradient accumulation
across microbatches, mean over n_micro, one AdamW update, loss metric
ce + 0.01·aux per microbatch.  ``TrainState`` keeps the pp=1 layout — grads
are unstacked back before the update — so optimizer, checkpointing and the
pp=1 path are untouched.  All four schedules reproduce the pp=1 step's
loss and post-update params to bf16-accumulation tolerance at
pp∈{2,4} × tp∈{1,2} × dp∈{1,2} (``tests/test_pipeline_1f1b.py``,
``tests/test_pipeline_3d.py``).

``ep=tp`` switches MoE layers from the default ETP dispatch (all experts
on every shard, expert-ff sharded, replicated routing) to true expert
parallelism on the same 'model' axis (paper §3.3): routed expert weights
live sharded on their *expert* dim (``(E/ep, h, h_E)`` per shard, full
hidden), each shard routes its own disjoint token chunk — the seq shard
under ``sp``; a ``shard_tokens_ep`` slice of the replicated residual
otherwise — buckets assignments by destination expert shard, and
exchanges ``(ep, C_send, h)`` send buffers via ``lax.all_to_all`` over
'model', runs the local ``(E/ep, C, h)`` grouped FFN and a2a's the
outputs back (``models.moe._moe_forward_ep``).  The shared expert stays
ETP (ff-sharded, every token through the f/g — or ğ/dual — pair), and
the router joins the post-loop 'model' psum: it is consumed inside the
token-sharded region, so its local grads are token-partial under EP
exactly as under SP.  The a2a dispatch group is the whole 'model' axis,
so the executor ties ``ep`` to ``tp`` (``parallel.tp.check_ep_supported``;
grouped sub-axis a2a remains estimator-only).

Scope notes: MoE aux uses the capacity dispatch and is pmean'd across
data shards (and, under ``sp``/``ep``, its load-balance means are
combined across the token shards so the aux value matches the
unsharded step exactly).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.notation import AttentionKind
from repro.core.parallel_config import ZeROStage
from repro.models import backend as B
from repro.models.layers import embed_apply
from repro.models.model import Model
from repro.models.pipeline import (check_pipeline_supported,
                                   chunked_partition, pipeline_stage_apply,
                                   stack_pipeline_params,
                                   unstack_pipeline_grads)
from repro.optim.adamw import TrainState, adamw_update
from repro.parallel.compat import shard_map
from repro.parallel.sharding import (grad_shardings, pipeline_stage_specs,
                                     state_shardings, zero3_stage_specs)
from repro.parallel.tp import (ce_sum_tp, check_ep_supported,
                               check_sp_supported, check_tp_supported,
                               copy_to_tp, embed_tp, gather_from_sp,
                               gather_params, tp_local_spec)
from repro.train.loop import TrainConfig, _split_micro
from repro.train.schedules import build_exec_tables, make_schedule

PyTree = Any

# Executor TP rules: like the §3 defaults, but experts shard their *ff* dim
# (ETP) instead of the expert dim (EP) — the router and capacity dispatch
# then run replicated and bit-identical on every 'model' shard, which the
# manual-collective construction requires (see parallel.tp).
_EXEC_TP_RULES = {"expert": None, "expert_ff": "model"}
# Executor EP rules (make_pipeline_train_step(..., ep=tp)): routed experts
# shard their *expert* dim across 'model' (the §3.3 default) and keep the
# full ff; the shared expert's 'ff' split is untouched (ETP).  Token
# exchange is then models.moe's explicit a2a dispatch.
_EXEC_EP_RULES = {"expert": "model", "expert_ff": None}


def _ce_mask(mask: Optional[jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    targets_shape = (tokens.shape[0], tokens.shape[1] - 1)
    if mask is None:
        return jnp.ones(targets_shape, jnp.float32)
    m = mask[:, 1:] if mask.shape == tokens.shape else mask
    return m.astype(jnp.float32)


def _ce_sum(logits: jnp.ndarray, tokens: jnp.ndarray,
            mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Unnormalized token-CE sum over the local batch shard (fp32), the
    summand of Model.loss's masked mean."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * _ce_mask(mask, tokens))


def _dyn(a: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)


def make_pipeline_train_step(model: Model, cfg: TrainConfig, mesh: Mesh, *,
                             schedule: str = "1f1b", n_chunks: int = 1,
                             zero: ZeROStage = ZeROStage.NONE,
                             sp: bool = False, ep: int = 1,
                             gate_compute: bool = True):
    """Build the jit-able schedule-driven pipeline step for ``mesh`` (axes
    ('pipe'[, 'data'][, 'model'])); pp = mesh.shape['pipe'], TP degree =
    mesh.shape['model'].  Same contract as ``make_train_step``.  ``zero``
    shards optimizer state (and grads for ``os+g``) across the per-stage DP
    group via sharding constraints; callers keeping state resident across
    steps should ``device_put`` it with
    ``parallel.sharding.state_shardings(abstract_state, mesh, zero,
    rules=pipeline_loop._EXEC_TP_RULES)`` — the executor's ETP expert
    layout (identical to the default rules for non-MoE models).

    ``sp=True`` turns on Megatron sequence parallelism (degree tied to the
    'model' axis size; requires tp > 1 and ``seq_len % tp == 0`` — see the
    module docstring for the boundary-operator construction).  The
    parameter/optimizer layout and ZeRO constraints are unchanged: SP only
    re-shards activations, so it composes with any ``zero`` stage.

    ``ep=tp`` turns on true expert parallelism for MoE layers (paper
    §3.3): routed expert weights shard their *expert* dim across 'model'
    (``_EXEC_EP_RULES``) and dispatch is the explicit all-to-all token
    exchange — see the module docstring.  Requires an MoE model with
    ``n_routed % ep == 0`` and, without ``sp``, a per-rank token count
    divisible by ``ep``; the a2a group is the whole 'model' axis, so only
    ``ep in (1, tp)`` is executable.  Composes with any schedule, ``sp``
    and ``zero``; callers keeping state resident should use the
    ``_EXEC_EP_RULES`` layout in ``state_shardings``.

    ``gate_compute=False`` disables the ``lax.cond`` gating of the tick
    body: every tick then runs the full active-branch program and selects
    between it and the no-op result with ``jnp.where`` — the pre-overlap
    masked-executor cost model with the overlap engine's numerics.  The
    active branch's arithmetic is identical either way, so gated and
    ungated steps agree bit-for-bit; the flag exists for exactly that A/B
    check (``tests/test_zb_equivalence.py``) and for isolating cond-related
    compiler issues."""
    spec, opts = model.spec, model.opts
    check_pipeline_supported(spec)
    if "pipe" not in mesh.axis_names:
        raise ValueError("pipeline step needs a 'pipe' mesh axis "
                         "(launch.mesh.make_production_mesh(pp=...))")
    tp = mesh.shape.get("model", 1)
    tp_axis = "model" if tp > 1 else None
    check_tp_supported(spec, tp)
    sp = bool(sp)
    if sp and not tp_axis:
        raise ValueError(
            "sp=True needs a 'model' mesh axis of size > 1: Megatron SP "
            "ties the sequence-parallel degree to TP")
    ep = int(ep)
    check_ep_supported(spec, tp, ep)
    rules = _EXEC_EP_RULES if ep > 1 else _EXEC_TP_RULES
    spec_run = tp_local_spec(spec, tp)
    # ZeRO-3 (os+g+params): bf16 working params live DP-sharded
    # (zero3_stage_specs) and every F/B tick all-gathers the chunk's slice
    # on use via parallel.tp.gather_params, whose backward reduce-scatters
    # the weight cotangent — summing the cross-DP grad contributions and
    # re-sharding in one collective, so the post-loop data psum is skipped
    # for exactly the gathered leaves.
    zp = zero == ZeROStage.OS_G_PARAMS
    S = mesh.shape["pipe"]
    M = cfg.n_micro
    sched = make_schedule(schedule, S, M, n_chunks=n_chunks)
    tab = build_exec_tables(sched)
    part = chunked_partition(spec, S, schedule=schedule,
                             n_chunks=sched.n_chunks)
    V, T, XS, GS = sched.n_chunks, tab.T, tab.x_slots, tab.g_slots
    SS = tab.s_slots
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    gemma = spec.name.startswith("gemma")
    masks_all = jnp.asarray(part.mask)              # (S, V, l_max)
    flags_all = jnp.asarray(part.moe_flag)
    first_all = jnp.asarray(part.first_flag)        # (S, V)
    last_all = jnp.asarray(part.last_flag)
    zb = schedule == "zb1p"
    tabs = {k: jnp.asarray(getattr(tab, k)) for k in (
        "f_act", "f_micro", "f_chunk", "f_xidx",
        "b_act", "b_micro", "b_chunk", "b_xidx", "b_gidx",
        "rfd_act", "rfd_idx", "rfu_act", "rfu_idx")
        + (("w_act", "w_micro", "w_chunk", "b_sidx", "w_sidx")
           if zb else ())}
    # Grad arrivals are consumed one tick late: the input-gradient computed
    # at tick t rides the scan carry, its ppermute is issued at the TOP of
    # tick t+1 (so the ring transfer overlaps t+1's forward compute) and the
    # payload lands in the grad ring just before t+1's backward reads it.
    # The receive tables shift down one tick to match; visibility is
    # unchanged — a strict-previous-tick dependency means the earliest
    # consumer runs at t+1, which now reads the payload the moment it lands,
    # and slot-reuse stays safe (the write lands strictly after the previous
    # occupant's last read at tick <= t, exactly as the end-of-tick write
    # scheme guaranteed).
    _shift = lambda a: np.concatenate([np.zeros_like(a[:1]), a[:-1]], axis=0)
    for k in ("rgd_act", "rgd_idx", "rgu_act", "rgu_idx"):
        tabs[k] = jnp.asarray(_shift(getattr(tab, k)))
    # gate every permute on its own table: 1f1b/interleaved move forwards
    # down-ring and gradients up-ring only — permuting the unused payload
    # would double boundary traffic per tick
    use_f_down = bool(tab.fsend_down.any())
    use_f_up = bool(tab.fsend_up.any())
    use_b_down = bool(tab.bsend_down.any())
    use_b_up = bool(tab.bsend_up.any())

    def _psum(x, axes):
        return jax.lax.psum(x, axes) if axes else x

    def _run(stacked: PyTree, slot_masks: jnp.ndarray,
             slot_flags: jnp.ndarray, firsts: jnp.ndarray,
             lasts: jnp.ndarray, toks: jnp.ndarray,
             mmask: Optional[jnp.ndarray], gdims: Optional[PyTree] = None):
        """shard_map body: returns (chunk-stacked fp32 grads, loss_sum)."""
        d = jax.lax.axis_index("pipe")
        p = jax.tree.map(lambda a: jnp.squeeze(a, 0), stacked)
        smask, sflag = slot_masks[0], slot_flags[0]     # (V, l_max) local
        first_l, last_l = firsts[0], lasts[0]           # (V,) local
        _, b_loc, s = toks.shape
        s_loc = s // tp if sp else s      # SP: boundary tensors seq-sharded
        h = spec.h
        adt = p["embed"]["w"].dtype
        p_layers = p["layers"]
        p_shared = {k: v for k, v in p.items() if k != "layers"}

        # ZeRO-3 gather-on-use helpers.  ``gdims`` (static ints, -1 = leaf
        # stays replicated) indexes the *stacked* tree; the squeeze above
        # removes the pipe dim (-1) and ``layers_at`` the chunk dim (-1
        # more), so chunk-level layer leaves gather at dm-2 and shared
        # leaves at dm-1.  In the backward each gather transposes to a
        # psum_scatter of the weight cotangent, so dpl/dps/stash emerge
        # shard-shaped and already cross-DP-summed.
        if zp and gdims is not None and data_axes:
            gdl = gdims["layers"]
            gds = {k: v for k, v in gdims.items() if k != "layers"}
            gather_l = lambda pl: jax.tree.map(
                lambda a, dm: a if dm < 0 else
                gather_params(a, data_axes, dm - 2), pl, gdl)
            gather_s = lambda ps: jax.tree.map(
                lambda a, dm: a if dm < 0 else
                gather_params(a, data_axes, dm - 1), ps, gds)
            gdims_g = dict(gds, layers=gdl)
        else:
            gather_l = gather_s = lambda t: t
            gdims_g = None

        def chunk_fn(pl, ps, x_recv, tok, mm, c, remat=True):
            """Uniform per-chunk program: embed (selected when the chunk is
            the first model chunk), the chunk's union slots, head + local CE
            sum (meaningful on the last model chunk, zero-cotangent
            elsewhere).  Under TP the embedding is row-sharded and the
            logits column-sharded on 'model' (vocab-parallel CE); under SP
            the residual in and out of the slots — and the returned ``y`` —
            is the (b, s/tp, h) seq shard, and the head gathers the
            final-norm output before the column-sharded projection.
            ``remat=False`` (zb1p's split backward) bypasses the slot
            checkpointing so each half of the B/W split replays the chunk
            exactly once."""
            if tp_axis:
                x0 = embed_tp(ps["embed"]["w"], tok, axis=tp_axis,
                              scale_by_dim=gemma, h=spec.h, sp=sp)
            else:
                x0 = embed_apply(ps["embed"], tok, scale_by_dim=gemma,
                                 h=spec.h)
            x = jnp.where(first_l[c] > 0.5, x0, x_recv)
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b_loc, s))
            y, aux = pipeline_stage_apply(pl, spec_run, opts, x, positions,
                                          smask[c], sflag[c], tp_axis,
                                          sp=sp, ep=ep, remat=remat)
            z = B.rmsnorm(ps["final_norm"], y, spec.norm_eps,
                          gemma_style=gemma, backend=B.resolve_backend(opts))
            w_out = ps["embed"]["w"].T if spec.tie_embeddings \
                else ps["head"]["w"]
            if tp_axis:
                zin = gather_from_sp(z, tp_axis, 1) if sp \
                    else copy_to_tp(z, tp_axis)
                logits = zin @ w_out
                ce = ce_sum_tp(logits, tok, _ce_mask(mm, tok), axis=tp_axis)
            else:
                logits = z @ w_out
                ce = _ce_sum(logits, tok, mm)
            return y, ce, aux

        def micro_at(arr, m):
            return jax.lax.dynamic_index_in_dim(arr, m, 0, keepdims=False)

        def count_g(tok, mm):
            return _psum(jnp.sum(_ce_mask(mm, tok)), data_axes)

        def layers_at(c):
            return jax.tree.map(lambda a: _dyn(a, c), p_layers)

        def _cond(pred, on_fn, off_fn):
            """The overlap engine's gate: run ``on_fn`` only when the tick
            table says so (idle/warmup/cooldown ticks cost ~0 — the no-op
            branch just threads the carried buffers through unchanged, so
            both branches return identical pytree shapes and XLA aliases
            the buffers).  With ``gate_compute=False`` both branches run
            and ``jnp.where`` selects — the pre-overlap masked cost model
            with bit-identical active arithmetic (the A/B reference)."""
            if gate_compute:
                return jax.lax.cond(pred, on_fn, off_fn)
            on_v, off_v = on_fn(), off_fn()
            return jax.tree.map(
                lambda a_, b_: jnp.where(pred, a_, b_), on_v, off_v)

        def _cotangents(tok, mm, c, dy):
            """Output cotangents of ``chunk_fn`` for retiring chunk ``c``:
            the boundary grad ``dy`` (zeroed on the last model chunk, whose
            ``y`` has no consumer), the CE mean cotangent (nonzero only on
            the last chunk) and the 0.01 aux weight (aux is a per-data-shard
            mean whose loss term is the pmean, so each shard carries
            1/data_size; grads are psummed over the data axes below)."""
            lastc = last_l[c]
            dy_cot = jnp.where(lastc < 0.5, dy, jnp.zeros((), dy.dtype))
            dce = lastc / jnp.maximum(count_g(tok, mm), 1.0)
            return dy_cot, dce, jnp.float32(0.01 / data_size)

        def tick(carry, t):
            if zb:
                xbuf, gbuf, gl, gsh, loss, aux_acc, dx_c, stash = carry
            else:
                xbuf, gbuf, gl, gsh, loss, aux_acc, dx_c = carry
            ring_dn = [(i, (i + 1) % S) for i in range(S)]
            ring_up = [(i, (i - 1) % S) for i in range(S)]

            def write(buf, act, idx, payload):
                i = idx[t, d]
                cur_v = _dyn(buf, i)
                val = jnp.where(act[t, d] > 0.5, payload, cur_v)
                return jax.lax.dynamic_update_index_in_dim(buf, val, i, 0)

            # -- issue: the PREVIOUS tick's input-gradient permutes.  The
            #    payload rode the scan carry, so the ring transfer is in
            #    flight while this tick's forward computes (ppermute stays
            #    outside the conds: it is a collective over 'pipe', where
            #    the gate predicates differ) -----------------------------
            if use_b_down:
                dx_dn = jax.lax.ppermute(dx_c, "pipe", ring_dn)
            if use_b_up:
                dx_up = jax.lax.ppermute(dx_c, "pipe", ring_up)

            # -- forward (cond-gated): the schedule's (micro, chunk) ------
            fm = tabs["f_micro"][t, d]
            fc = tabs["f_chunk"][t, d]

            def f_on():
                x_in = _dyn(xbuf, tabs["f_xidx"][t, d])
                tok_f = micro_at(toks, fm)
                mm_f = None if mmask is None else micro_at(mmask, fm)
                y_, ce_sum, aux_f = chunk_fn(gather_l(layers_at(fc)),
                                             gather_s(p_shared), x_in,
                                             tok_f, mm_f, fc)
                ce_m = _psum(ce_sum, data_axes) / jnp.maximum(
                    count_g(tok_f, mm_f), 1.0)
                return y_, loss + last_l[fc] * ce_m, aux_acc + aux_f

            def f_off():
                return jnp.zeros((b_loc, s_loc, h), adt), loss, aux_acc

            y, loss, aux_acc = _cond(tabs["f_act"][t, d] > 0.5, f_on, f_off)

            # -- issue: this tick's forward-boundary permutes (consumed
            #    after the backward below — the transfer overlaps B/W) ----
            if use_f_down:
                y_dn = jax.lax.ppermute(y, "pipe", ring_dn)
            if use_f_up:
                y_up = jax.lax.ppermute(y, "pipe", ring_up)

            # -- consume: the grad payloads issued at the top of the tick
            #    land in the ring just before the backward reads them -----
            if use_b_down:
                gbuf = write(gbuf, tabs["rgd_act"], tabs["rgd_idx"], dx_dn)
            if use_b_up:
                gbuf = write(gbuf, tabs["rgu_act"], tabs["rgu_idx"], dx_up)

            # -- backward (cond-gated): retire (micro, chunk) -------------
            bm = tabs["b_micro"][t, d]
            bc = tabs["b_chunk"][t, d]

            if zb:
                # zb1p's ZB-H1 split: B runs the fused chunk vjp ONCE,
                # without slot checkpointing — the split stashes the fp32
                # pending-dW instead of recomputing activations, so the
                # replay the checkpoint policy would pay is gone (the
                # memory-for-time trade estimate_memory prices via
                # zb_pending_peak).  dx and the shared embed/head/norm
                # grads retire here; the per-layer dW parks in its stash
                # slot until the schedule's dedicated W tick below.
                def b_on():
                    tok_b = micro_at(toks, bm)
                    mm_b = None if mmask is None else micro_at(mmask, bm)
                    x_sv = _dyn(xbuf, tabs["b_xidx"][t, d])
                    dy = _dyn(gbuf, tabs["b_gidx"][t, d])
                    pl_b = layers_at(bc)
                    _, vjp_fn = jax.vjp(
                        lambda pl_, ps_, x_: chunk_fn(gather_l(pl_),
                                                      gather_s(ps_), x_,
                                                      tok_b, mm_b, bc,
                                                      remat=False),
                        pl_b, p_shared, x_sv)
                    dpl, dps, dx_ = vjp_fn(_cotangents(tok_b, mm_b, bc, dy))
                    pend = jax.tree.map(
                        lambda g_: g_.astype(jnp.float32), dpl)
                    stash_ = jax.tree.map(
                        lambda st, g_: jax.lax.dynamic_update_index_in_dim(
                            st, g_, tabs["b_sidx"][t, d], 0), stash, pend)
                    gsh_ = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), gsh, dps)
                    return stash_, gsh_, dx_

                def b_off():
                    return stash, gsh, jnp.zeros((b_loc, s_loc, h), adt)

                stash, gsh, dx = _cond(tabs["b_act"][t, d] > 0.5, b_on,
                                       b_off)

                # -- weight-grad tick (cond-gated): the deferred half is a
                #    pure stash -> accumulator flush, so cooldown fills
                #    with cheap W work exactly as ZB-H1 intends (fp32 adds
                #    in microbatch order — the same reduction order as the
                #    fused path, just later) ------------------------------
                wc = tabs["w_chunk"][t, d]

                def w_on():
                    pend = jax.tree.map(
                        lambda st: _dyn(st, tabs["w_sidx"][t, d]), stash)
                    cur = jax.tree.map(lambda a: _dyn(a, wc), gl)
                    upd = jax.tree.map(lambda a, g_: a + g_, cur, pend)
                    return jax.tree.map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u, wc, 0), gl, upd)

                def w_off():
                    return gl

                gl = _cond(tabs["w_act"][t, d] > 0.5, w_on, w_off)
            else:
                def b_on():
                    tok_b = micro_at(toks, bm)
                    mm_b = None if mmask is None else micro_at(mmask, bm)
                    x_sv = _dyn(xbuf, tabs["b_xidx"][t, d])
                    dy = _dyn(gbuf, tabs["b_gidx"][t, d])
                    pl_b = layers_at(bc)
                    _, vjp_fn = jax.vjp(
                        lambda pl_, ps_, x_: chunk_fn(gather_l(pl_),
                                                      gather_s(ps_), x_,
                                                      tok_b, mm_b, bc),
                        pl_b, p_shared, x_sv)
                    dpl, dps, dx_ = vjp_fn(_cotangents(tok_b, mm_b, bc, dy))
                    cur = jax.tree.map(lambda a: _dyn(a, bc), gl)
                    upd = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), cur, dpl)
                    gl_ = jax.tree.map(
                        lambda a, u: jax.lax.dynamic_update_index_in_dim(
                            a, u, bc, 0), gl, upd)
                    gsh_ = jax.tree.map(
                        lambda a, g_: a + g_.astype(jnp.float32), gsh, dps)
                    return gl_, gsh_, dx_

                def b_off():
                    return gl, gsh, jnp.zeros((b_loc, s_loc, h), adt)

                gl, gsh, dx = _cond(tabs["b_act"][t, d] > 0.5, b_on, b_off)

            # -- consume: this tick's forward-boundary payloads (issued
            #    before the backward) land in the rings ------------------
            if use_f_down:
                xbuf = write(xbuf, tabs["rfd_act"], tabs["rfd_idx"], y_dn)
            if use_f_up:
                xbuf = write(xbuf, tabs["rfu_act"], tabs["rfu_idx"], y_up)
            out = (xbuf, gbuf, gl, gsh, loss, aux_acc, dx)
            return (out + (stash,) if zb else out), None

        zeros_like_f32 = lambda tree: jax.tree.map(
            lambda a: jnp.zeros(a.shape, jnp.float32), tree)
        init = (jnp.zeros((V * XS, b_loc, s_loc, h), adt),
                jnp.zeros((V * GS, b_loc, s_loc, h), adt),
                zeros_like_f32(p_layers),
                zeros_like_f32(p_shared),
                jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32),
                jnp.zeros((b_loc, s_loc, h), adt))    # in-flight dx carry
        if zb:
            # fp32 pending-dW stash: one chunk-shaped grad pytree per
            # stash slot, written at B, flushed at the dedicated W tick
            init = init + (jax.tree.map(
                lambda a: jnp.zeros((V * SS,) + a.shape[1:], jnp.float32),
                p_layers),)
        fin, _ = jax.lax.scan(tick, init, jnp.arange(T))
        _, _, gl, gsh, loss, aux_acc = fin[:6]

        g = dict(gsh, layers=gl)
        if sp or ep > 1:
            # Token-sharded grad completion: weights applied *inside* a
            # token-sharded region accumulate grads from their shard's
            # tokens only; one psum over 'model' assembles the full
            # gradient for exactly those leaves.  Under SP that is the
            # norm scales, the MoE router and MLA's replicated latent
            # towers (which run without copy_to_tp under SP — the entry
            # ğ's reduce-scatter backward does the cross-shard sum — so
            # their grads are head-partial).  Under EP (with or without
            # SP) the router is consumed on each rank's disjoint token
            # chunk, so it needs the same completion; the expert weights
            # themselves do NOT — the a2a already delivered every rank
            # the full token set bound for its experts, so their local
            # grads are exact.  Every other leaf stays exact-local (the
            # boundary operators carry the cross-shard sums in their
            # backward rules) and must NOT be psummed — that would scale
            # it by tp.
            lay = dict(g["layers"])
            if sp:
                for k in ("ln1", "ln2"):
                    lay[k] = jax.lax.psum(lay[k], tp_axis)
            if "moe" in lay:
                lay["moe"] = dict(
                    lay["moe"],
                    router=jax.lax.psum(lay["moe"]["router"], tp_axis))
            if sp and spec.attention == AttentionKind.MLA:
                attn_g = dict(lay["attn"])
                for k in ("w_dq", "w_dkv", "w_kr", "q_norm", "kv_norm"):
                    attn_g[k] = jax.lax.psum(attn_g[k], tp_axis)
                lay["attn"] = attn_g
            g = dict(g, layers=lay)
            if sp:
                g = dict(g, final_norm=jax.lax.psum(g["final_norm"],
                                                    tp_axis))
        if gdims_g is not None:
            # ZeRO-3: gathered leaves' grads were already cross-DP-summed
            # (and re-sharded) by gather_params' backward psum_scatter —
            # a data psum here would double-count them.  Replicate-fallback
            # leaves (dm < 0) still need the sum.
            g = jax.tree.map(
                lambda a, dm: (_psum(a, data_axes) if dm < 0 else a)[None],
                g, gdims_g)
        else:
            g = jax.tree.map(lambda a: _psum(a, data_axes)[None], g)
        aux_acc = jax.lax.pmean(aux_acc, data_axes) if data_axes else aux_acc
        loss_sum = jax.lax.psum(loss + 0.01 * aux_acc, "pipe")
        return g, loss_sum

    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]

    def _zero_constrain(st: TrainState) -> TrainState:
        """ZeRO residency: pin {master, m, v} to their per-stage-DP-group
        shardings (state keeps the pp=1 layout; the 'data'(+'pod') axes of
        this mesh *are* the within-stage DP group because PP carves the
        leading 'pipe' axis out of data)."""
        sh = state_shardings(st, mesh, zero, rules=rules)
        wsc = jax.lax.with_sharding_constraint
        st = st._replace(master=wsc(st.master, sh.master),
                         m=wsc(st.m, sh.m), v=wsc(st.v, sh.v))
        if zp:
            # ZeRO-3: the bf16 working params are DP-sharded at rest too
            st = st._replace(params=wsc(st.params, sh.params))
        return st

    def step(state: TrainState, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        micro = _split_micro(batch, M)
        toks = micro["tokens"]
        if toks.shape[1] % data_size:
            raise ValueError(
                f"micro-batch size {toks.shape[1]} must divide the data axes "
                f"(size {data_size})")
        if sp:
            check_sp_supported(spec, tp, toks.shape[2])
        if ep > 1 and not sp:
            # the EP entry slices each rank's replicated (b_loc·s) token
            # set into ep chunks; under sp the residual already arrives
            # token-sharded and no slice happens
            check_ep_supported(
                spec, tp, ep,
                tokens_per_rank=(toks.shape[1] // data_size) * toks.shape[2])
        if zero != ZeROStage.NONE:
            state = _zero_constrain(state)
        stacked = stack_pipeline_params(state.params, spec, S,
                                        schedule=schedule, n_chunks=V)
        if zp and data_axes:
            stage_specs, gdims = zero3_stage_specs(stacked, mesh,
                                                   rules=rules)
        else:
            stage_specs = pipeline_stage_specs(stacked, mesh, rules=rules)
            gdims = None
        dspec = tuple(data_axes) if data_axes else None
        margs = (toks,)
        mspecs = (P(None, dspec, None),)
        if "mask" in micro:
            margs += (micro["mask"],)
            mspecs += (P(None, dspec, *(None,) * (micro["mask"].ndim - 2)),)

        def inner(stacked_l, masks_l, flags_l, firsts_l, lasts_l, toks_l,
                  *rest):
            return _run(stacked_l, masks_l, flags_l, firsts_l, lasts_l,
                        toks_l, rest[0] if rest else None, gdims=gdims)

        g_st, loss_sum = shard_map(
            inner, mesh=mesh,
            in_specs=(stage_specs, P("pipe", None, None), P("pipe", None, None),
                      P("pipe", None), P("pipe", None)) + mspecs,
            out_specs=(stage_specs, P()),
        )(stacked, masks_all, flags_all, first_all, last_all, *margs)
        grads = unstack_pipeline_grads(g_st, state.params, spec, S,
                                       schedule=schedule, n_chunks=V)
        grads = jax.tree.map(lambda a: a / M, grads)
        if zero in (ZeROStage.OS_G, ZeROStage.OS_G_PARAMS):
            # ZeRO-2: reduce-scatter the fp32 accumulation buffers onto the
            # per-stage DP group before the (sharded) optimizer update
            grads = jax.lax.with_sharding_constraint(
                grads, grad_shardings(state.params, mesh, zero,
                                      rules=rules))
        new_state, opt_metrics = adamw_update(state, grads, cfg.adamw)
        if zero != ZeROStage.NONE:
            new_state = _zero_constrain(new_state)
        metrics = {"loss": loss_sum / M, **opt_metrics}
        return new_state, metrics

    return step
