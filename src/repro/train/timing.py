"""Shared wall-clock timing for the benchmark harnesses.

Measuring jitted JAX callables correctly needs three things the naive
``time.perf_counter`` loop gets wrong:

* **Warmup outside the timed region** — the first call pays tracing +
  compilation (seconds), which would swamp a microsecond-scale kernel.
* **Blocking inside each timed window** — JAX dispatch is async; without
  ``jax.block_until_ready`` the "measured" time is enqueue latency.
* **Median, not mean** — a single OS scheduler hiccup inflates a mean
  arbitrarily; the median of k independent windows is robust to it.
  (``benchmarks/kernel_bench.py`` historically reported a mean over one
  blocked loop; it now routes through :func:`time_callable`.)

The module is deliberately dependency-light (``jax`` only when a result
needs blocking) so ``benchmarks/step_bench.py`` can import the row-merge
helper before setting ``XLA_FLAGS`` and importing jax.

Also here: :func:`merge_rows`, the newest-wins dedupe both BENCH_*.json
writers share — the same policy ``benchmarks/validate_memory`` applies to
its per-config artifacts (latest row for a config key replaces older ones).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TimingResult:
    """Per-window wall-clock samples for one callable."""

    times_s: Tuple[float, ...]    # one entry per timed window, seconds
    warmup_s: float               # first (untimed-loop) call: trace+compile

    @property
    def median_s(self) -> float:
        return statistics.median(self.times_s)

    @property
    def mean_s(self) -> float:
        return statistics.fmean(self.times_s)

    @property
    def min_s(self) -> float:
        return min(self.times_s)

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6


def time_callable(fn: Callable[..., Any], *args: Any,
                  iters: int = 5, warmup: int = 1,
                  block: bool = True) -> TimingResult:
    """Median-of-``iters`` wall clock for ``fn(*args)``.

    ``warmup`` calls run first (blocked, untimed) so compilation and cache
    population never land in a sample; the first warmup's duration is kept
    as ``warmup_s`` for reporting compile cost.  Each of the ``iters``
    timed windows wraps exactly one call and blocks on its result before
    reading the clock, so async dispatch cannot shrink a sample.

    ``block=False`` skips ``jax.block_until_ready`` for callables that are
    already synchronous (pure-Python work in tests) — and keeps this module
    importable without jax.
    """
    if iters < 1 or warmup < 0:
        raise ValueError(f"need iters >= 1, warmup >= 0 (got {iters}, {warmup})")

    def ready(x):
        if block:
            import jax
            return jax.block_until_ready(x)
        return x

    t0 = time.perf_counter()
    out = None
    for _ in range(warmup):
        out = ready(fn(*args))
    warmup_s = time.perf_counter() - t0
    del out
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return TimingResult(times_s=tuple(samples), warmup_s=warmup_s)


def merge_rows(existing: Sequence[Dict[str, Any]],
               new: Sequence[Dict[str, Any]],
               key_fields: Sequence[str]) -> List[Dict[str, Any]]:
    """Newest-wins merge of benchmark rows on ``key_fields``.

    ``new`` rows replace ``existing`` rows with the same config key (missing
    key fields compare as None, so schema growth keeps old rows distinct
    rather than silently clobbering them).  Order: stable sort by the
    stringified key, matching ``validate_memory``'s artifact tables so
    re-runs produce minimal diffs in the committed JSON.
    """
    by_key: Dict[Tuple[str, ...], Dict[str, Any]] = {}
    for row in list(existing) + list(new):
        key = tuple(str(row.get(f)) for f in key_fields)
        by_key[key] = row
    return [by_key[k] for k in sorted(by_key)]
