from .loop import TrainConfig, make_train_step, train

__all__ = ["TrainConfig", "make_train_step", "train"]
