from .loop import TrainConfig, make_train_step, train
from .pipeline_loop import make_pipeline_train_step
from .timing import TimingResult, merge_rows, time_callable

__all__ = ["TimingResult", "TrainConfig", "make_pipeline_train_step",
           "make_train_step", "merge_rows", "time_callable", "train"]
