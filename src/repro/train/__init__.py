from .loop import TrainConfig, make_train_step, train
from .pipeline_loop import make_pipeline_train_step

__all__ = ["TrainConfig", "make_pipeline_train_step", "make_train_step",
           "train"]
