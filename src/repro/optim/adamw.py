"""AdamW with the paper's exact mixed-precision state layout (Table 7):

  weights   BF16  (2 B)   — the live parameters used by forward/backward
  gradients FP32  (4 B)   — the accumulation buffer across micro-batches
  optimizer:
    master copy  FP32 (4 B)
    momentum     BF16 (2 B)
    variance     BF16 (2 B)

Total optimizer bytes/param = 8, matching §4's ZeRO arithmetic.  ZeRO
sharding of {master, m, v} (stage os), + grads (os+g), + params
(os+g+params) is applied by the launcher through output shardings — the
math here is sharding-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class TrainState(NamedTuple):
    step: jnp.ndarray          # () int32
    params: PyTree             # bf16 live weights
    master: PyTree             # fp32 copy (optimizer)
    m: PyTree                  # bf16 momentum
    v: PyTree                  # bf16 variance


def init_train_state(params: PyTree) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
        m=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params),
        v=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params),
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(state: TrainState, grads: PyTree, cfg: AdamWConfig
                 ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    """grads: fp32 pytree (the Table-7 accumulation buffer)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        new_master = master - cfg.lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                        + cfg.weight_decay * master)
        return (m32.astype(jnp.bfloat16), v32.astype(jnp.bfloat16), new_master)

    flat = jax.tree.map(upd, grads, state.m, state.v, state.master)
    new_m = jax.tree.map(lambda x: x[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.map(lambda x: x[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp: mp.astype(jnp.bfloat16), new_master)
    return TrainState(step=step, params=new_params, master=new_master,
                      m=new_m, v=new_v), {"grad_norm": gnorm}
