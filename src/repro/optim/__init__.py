from .adamw import AdamWConfig, TrainState, adamw_update, init_train_state

__all__ = ["AdamWConfig", "TrainState", "adamw_update", "init_train_state"]
