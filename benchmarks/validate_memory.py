"""Validation: the paper's analytical memory model vs XLA ground truth.

For each (arch, shape) the dry-run compiled, compare:

  * analytic state bytes  — repro.core.zero_memory under the ParallelConfig
    equivalent of the mesh (TP=model axis, DP=data axis, EP=min(model, E),
    ZeRO per the dry-run's --zero), params+optimizer (persistent inputs);
  * XLA argument bytes    — compiled.memory_analysis().argument_size_in_bytes
    minus the (analytically known) batch/cache input bytes;
  * analytic activations  — stage_activation_bytes (AC policy as lowered)
    vs XLA temp bytes (upper-bounded by temps: XLA temps also hold grads,
    logits and transient buffers — reported as a ratio, not an equality).

Writes benchmarks/artifacts/validation.json and prints a markdown table.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
DRY = os.path.join(ART, "dryrun")
GiB = 2 ** 30


def _batch_input_bytes(arch: str, shape: str) -> int:
    from repro.configs import get_spec
    from repro.core.notation import FamilyKind
    from repro.launch.specs import SHAPES
    spec = get_spec(arch)
    info = SHAPES[shape]
    n = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1) * 4
    if spec.family == FamilyKind.VLM and info["kind"] != "decode":
        n += info["batch"] * min(256, info["seq"] // 4) * spec.h * 2
    if spec.encoder is not None and info["kind"] != "decode":
        n += info["batch"] * spec.encoder.n_ctx * spec.h * 2
    return n


def _cache_bytes(arch: str, shape: str, n_chips: int) -> int:
    """Per-device cache input bytes for decode shapes — exact: walks the
    abstract cache and applies the SAME placement rule the dry-run sharded
    with (launch.specs.cache_placement)."""
    import jax
    from repro.configs import get_spec
    from repro.launch.specs import (SHAPES, cache_divisor, input_specs,
                                    spec_for_shape)
    from repro.models import build_model
    spec = spec_for_shape(get_spec(arch), shape)
    model = build_model(spec)
    ins = input_specs(get_spec(arch), shape, model=model)
    data_ax = n_chips // 16
    total = 0
    for leaf in jax.tree.leaves(ins["cache"]):
        import math
        n = math.prod(leaf.shape) if leaf.shape else 1
        total += (n * leaf.dtype.itemsize
                  // cache_divisor(leaf.shape, data_ax, 16))
    return total


def validate_one(arch: str, shape: str, mesh_tag: str = "pod16x16",
                 zero: str = "os+g") -> Optional[Dict[str, Any]]:
    from repro.configs import get_spec
    from repro.core import estimate_memory, zero_memory
    from repro.core.parallel_config import ZeROStage, RecomputePolicy
    from repro.launch.specs import SHAPES

    path = os.path.join(DRY, f"{arch}__{shape}__{mesh_tag}.json")
    if not os.path.exists(path):
        # dryrun tags use the CLI arch spelling (dots as underscores)
        path = os.path.join(
            DRY, f"{arch.replace('.', '_')}__{shape}__{mesh_tag}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": rec.get("status")}

    spec = get_spec(arch)
    info = SHAPES[shape]
    n_chips = 512 if "2x16" in mesh_tag else 256
    model_ax = 16
    data_ax = n_chips // 16
    ep = min(model_ax, spec.moe.n_routed) if spec.is_moe else 1
    from repro.core.parallel_config import ParallelConfig
    per_dev_batch = max(info["batch"] // data_ax, 1)
    cfg = ParallelConfig(dp=data_ax, tp=model_ax, pp=1, ep=ep, etp=1,
                         sp=True, zero=ZeROStage(zero),
                         recompute=RecomputePolicy.NONE,
                         micro_batch=per_dev_batch, seq_len=info["seq"])

    state = zero_memory(spec, cfg)
    if info["kind"] == "train":
        analytic_args = state.params + state.optimizer
    else:
        analytic_args = state.params
    xla_args = rec["memory"]["argument_size_in_bytes"]
    io_bytes = _batch_input_bytes(arch, shape) // max(data_ax, 1)
    if info["kind"] == "decode":
        io_bytes += _cache_bytes(arch, shape, n_chips)   # already per-device
    xla_state = max(xla_args - io_bytes, 1)

    out = {
        "arch": arch, "shape": shape, "status": "ok",
        "analytic_state_bytes": int(analytic_args),
        "xla_state_bytes": int(xla_state),
        "state_ratio": analytic_args / xla_state,
        "xla_temp_bytes": rec["memory"]["temp_size_in_bytes"],
    }
    if info["kind"] == "train":
        from repro.core import stage_activation_bytes
        act = stage_activation_bytes(spec, cfg)
        # XLA temps also hold fp32 grads + logits + transients
        grads = state.grads
        logits = per_dev_batch * info["seq"] * spec.vocab * 4 // model_ax
        out["analytic_act_bytes"] = int(act)
        out["analytic_temp_floor"] = int(act + grads + logits)
        out["temp_ratio"] = (act + grads + logits) / max(
            rec["memory"]["temp_size_in_bytes"], 1)
    return out


def _parse_mesh_tag(tag: str):
    """'pod16x16' / 'pod2x16x16' / 'pod16x2' -> (n_pods, data, model)."""
    body = tag[len("pod"):]
    pods = 1
    if body.startswith("2x") and body.count("x") == 2:
        pods, body = 2, body[2:]
    data, model = (int(x) for x in body.split("x"))
    return pods, data, model


def _parse_sp_tag(rec: Dict[str, Any], path: Optional[str] = None) -> int:
    """SP degree of a ``--pp`` artifact: the explicit ``sp`` field on new
    records, else the ``__sp<N>`` tag component, else the legacy default —
    older artifacts were analysed with ``sp=True`` hard-coded, i.e.
    sp == tp (the mesh tag's model axis) — so their rows keep the divisor
    their analytic columns actually used."""
    if "sp" in rec:
        return int(rec["sp"])
    if path:
        import re
        m = re.search(r"__sp(\d+)", os.path.basename(path))
        if m:
            return int(m.group(1))
    if "tp" in rec:
        return int(rec["tp"])
    try:
        return _parse_mesh_tag(rec["mesh"])[2]
    except Exception:
        return 1     # unparseable mesh tag: claim no divisor, don't fabricate


def _parse_ep_tag(rec: Dict[str, Any], path: Optional[str] = None) -> int:
    """EP degree of a ``--pp`` artifact: the explicit ``ep`` field on new
    records, else the ``__ep<N>`` tag component, else the legacy default —
    older artifacts were analysed with ``ep = min(tp, n_routed)`` for MoE
    archs (1 for dense), so their rows keep the divisor their analytic
    columns actually used."""
    if "ep" in rec:
        return int(rec["ep"])
    if path:
        import re
        m = re.search(r"__ep(\d+)", os.path.basename(path))
        if m:
            return int(m.group(1))
    try:
        from repro.configs import get_spec
        spec = get_spec(rec["arch"])
        if spec.is_moe:
            tp = int(rec["tp"]) if "tp" in rec \
                else _parse_mesh_tag(rec["mesh"])[2]
            return min(tp, spec.moe.n_routed)
    except Exception:
        pass
    return 1


def _parse_backend_tag(rec: Dict[str, Any], path: Optional[str] = None) -> str:
    """Kernel backend of a ``--pp`` artifact: the explicit ``backend`` field
    on new records, else the ``__pallas`` tag component, else "reference"
    (every pre-backend artifact ran the jnp reference path)."""
    if "backend" in rec:
        return str(rec["backend"])
    if path and "__pallas" in os.path.basename(path):
        return "pallas"
    return "reference"


def validate_pp(arch: str, shape: str, pp: int,
                mesh_tag: str = "pod16x16", schedule: str = "1f1b",
                n_chunks: int = 1, zero: str = "os+g", sp: int = 1,
                ep: Optional[int] = None, backend: str = "reference",
                tag_suffix: str = "") -> Optional[Dict[str, Any]]:
    """Per-rank validation of a ``dryrun --pp N [--schedule ...]`` artifact:
    XLA's per-rank temp bytes (activations + grads + transients of the rank
    program, which holds the schedule's in-flight microbatch counts for
    that rank) against ``estimate_memory(spec, cfg, stage=r,
    schedule=...)``.

    The check is the *direction* of the schedule's residency profile:
    under 1f1b and interleaved, rank 0 must not be lighter than the last
    rank (the §6 staircase); under dualpipe the analytic profile is
    near-flat (≈ pp+1 everywhere) and the measured ratio must stay inside a
    band around 1.  Run the dry-run with ``--n-micro >= pp``; with fewer
    microbatches every rank holds one in flight and the ratio degenerates
    to ~1."""
    sched_tag = "" if schedule == "1f1b" else f"__{schedule}{n_chunks}"
    zero_tag = "" if zero == "os+g" else f"__z{zero.replace('+', '')}"
    sp_tag = "" if sp == 1 else f"__sp{sp}"
    ep_tag = "" if ep is None else f"__ep{ep}"
    bk_tag = "" if backend == "reference" else "__pallas"
    path = os.path.join(
        DRY, f"{arch}__{shape}__{mesh_tag}__pp{pp}{sched_tag}{zero_tag}"
             f"{sp_tag}{ep_tag}{bk_tag}{tag_suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return _validate_pp_rec(rec, path)


def _validate_pp_rec(rec: Dict[str, Any],
                     path: Optional[str] = None) -> Dict[str, Any]:
    arch, shape, pp = rec["arch"], rec["shape"], rec["pp"]
    mesh_tag = rec["mesh"]
    schedule = rec.get("schedule", "1f1b")
    sp = _parse_sp_tag(rec, path)
    ep = _parse_ep_tag(rec, path)
    backend = _parse_backend_tag(rec, path)
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "pp": pp,
                "schedule": schedule, "n_chunks": rec.get("n_chunks", 1),
                "tp": rec.get("tp"), "sp": sp, "ep": ep, "backend": backend,
                "zero": rec.get("zero",
                                rec.get("options", {}).get("zero", "os+g")),
                "recompute": rec.get("options", {}).get("recompute", "none"),
                "n_micro": max(rec.get("options", {}).get("n_micro", 1), 1),
                "status": rec.get("status")}
    stages = rec["stages"]
    temps = [s["memory"].get("temp_size_in_bytes", 0) for s in stages]
    acts = [s["analytic"]["activations"] for s in stages]
    # Ranks holding the last model chunk also hold the fp32 logits/CE
    # buffers the activation model deliberately excludes (same adjustment
    # validate_one makes) — subtract the analytically known size before
    # comparing shape.  Under dualpipe both boundary ranks hold a head copy
    # (rank pp-1 via the forward direction, rank 0 via the reverse).
    from repro.configs import get_spec
    from repro.launch.specs import SHAPES
    spec = get_spec(arch)
    info = SHAPES[shape]
    pods, data, model_ax = _parse_mesh_tag(mesh_tag)
    n_micro = max(rec.get("options", {}).get("n_micro", 1), 1)
    data_ax = max(data // pp, 1) * pods
    b_dev = max(info["batch"] // n_micro // max(data_ax, 1), 1)
    logits = b_dev * info["seq"] * spec.vocab * 4
    if spec.vocab % model_ax == 0:
        logits //= model_ax
    head_ranks = {pp - 1} if schedule != "dualpipe" else {0, pp - 1}
    adj = list(temps)
    for r in head_ranks:
        adj[r] = max(adj[r] - logits, 1)
    m_ratio = adj[0] / max(adj[-1], 1)
    a_ratio = acts[0] / max(acts[-1], 1)
    if a_ratio > 1.05:          # analytic staircase falls (1f1b, interleaved)
        direction_ok = adj[0] >= adj[-1]
    elif a_ratio < 0.95:
        direction_ok = adj[0] <= adj[-1]
    else:                       # analytic near-flat (dualpipe)
        direction_ok = 0.6 <= m_ratio <= 1.67
    return {
        "arch": arch, "shape": shape, "pp": pp, "status": "ok",
        "schedule": schedule, "n_chunks": rec.get("n_chunks", 1),
        "tp": rec.get("tp", model_ax), "sp": sp, "ep": ep, "backend": backend,
        "zero": rec.get("zero", rec.get("options", {}).get("zero", "os+g")),
        "recompute": rec.get("options", {}).get("recompute", "none"),
        "n_micro": n_micro,
        "stages": [{
            "stage": s["stage"], "layers": s["layers"],
            "in_flight": s["in_flight"],
            "chunks": s.get("chunks"),
            "xla_temp_bytes": temps[i],
            # Measured persistent-input bytes and the analytic param-state
            # columns: the pair the ZeRO ladder acceptance compares —
            # ``--zero os+g+params`` rows must show both shrink vs the
            # matching os+g row (params shard over DP; the gather
            # transient is the price of re-assembly on use).
            "xla_arg_bytes": s["memory"].get("argument_size_in_bytes", 0),
            "analytic_param_bytes": s["analytic"].get("params", 0),
            "analytic_gather_bytes": s["analytic"].get(
                "gather_transient", 0),
            "analytic_act_bytes": acts[i],
            "analytic_total_bytes": s["analytic"]["total"],
        } for i, s in enumerate(stages)],
        "measured_ratio_stage0_over_last": m_ratio,
        "analytic_ratio_stage0_over_last": a_ratio,
        "direction_ok": direction_ok,
    }


def _pp_artifacts() -> List[Dict[str, Any]]:
    """One validation row per distinct (arch, shape, pp, schedule, n_chunks,
    tp, zero, sp, ep, n_micro) configuration.  Artifacts are deduped on
    that key — re-runs under a different tag suffix (e.g. legacy ``__nm8``
    files next to fresh defaults) previously appended duplicate rows to
    validation_pp.json; now the newest artifact (mtime) wins.  ``sp``/``ep``
    come from the record or the ``__sp<N>``/``__ep<N>`` tags, so sp (ep) =1
    and =tp probes of the same mesh coexist as separate rows — the pairs
    the /sp- and /ep-divisor acceptance checks compare."""
    import glob
    by_key: Dict[Any, Dict[str, Any]] = {}
    paths = sorted(glob.glob(os.path.join(DRY, "*__pp*.json")),
                   key=os.path.getmtime)
    for p in paths:
        with open(p) as f:
            rec = json.load(f)
        if "pp" not in rec:
            continue
        row = _validate_pp_rec(rec, p)
        key = (row.get("arch"), row.get("shape"), row.get("pp"),
               row.get("schedule"), row.get("n_chunks"), row.get("tp"),
               row.get("zero"), row.get("sp"), row.get("ep"),
               row.get("backend"), row.get("recompute"), row.get("n_micro"))
        by_key[key] = row            # newest artifact wins
    return [by_key[k] for k in sorted(by_key, key=lambda k: tuple(map(str, k)))]


def main():
    from repro.configs import ASSIGNED
    from repro.launch.specs import SHAPES
    rows: List[Dict[str, Any]] = []
    for a in ASSIGNED:
        for s in SHAPES:
            r = validate_one(a, s)
            if r:
                rows.append(r)
    with open(os.path.join(ART, "validation.json"), "w") as f:
        json.dump(rows, f, indent=1)
    ok = [r for r in rows if r.get("status") == "ok"]
    print("| arch | shape | analytic state | XLA state | ratio |"
          " temp floor/XLA |")
    print("|---|---|---|---|---|---|")
    for r in ok:
        tr = r.get("temp_ratio")
        print(f"| {r['arch']} | {r['shape']} | "
              f"{r['analytic_state_bytes']/GiB:.2f} GiB | "
              f"{r['xla_state_bytes']/GiB:.2f} GiB | "
              f"{r['state_ratio']:.2f} | "
              + (f"{tr:.2f} |" if tr else "- |"))
    ratios = [r["state_ratio"] for r in ok]
    if ratios:
        print(f"\nstate-bytes agreement: median {np.median(ratios):.3f}, "
              f"[{min(ratios):.2f}, {max(ratios):.2f}] over {len(ok)} combos")

    pp_rows = _pp_artifacts()
    if pp_rows:
        with open(os.path.join(ART, "validation_pp.json"), "w") as f:
            json.dump(pp_rows, f, indent=1)
        print("\n## Per-rank schedule residency (dryrun --pp [--tp --zero "
              "--sp --ep --schedule]) vs estimate_memory(stage=r, "
              "schedule=...)")
        print("| arch | shape | pp | tp | zero | sp | ep | backend | ac |"
              " schedule | n_micro | rank0/last XLA (logits-adj) |"
              " rank0/last analytic act | direction |")
        print("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|")
        for r in pp_rows:
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['pp']} |"
                      f" {r.get('tp', '-')} | {r.get('zero', '-')} |"
                      f" {r.get('sp', '-')} | {r.get('ep', '-')} |"
                      f" {r.get('backend', 'reference')} |"
                      f" {r.get('recompute', '-')} |"
                      f" {r.get('schedule', '1f1b')} | - | - | - |"
                      f" {r.get('status')} |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['pp']} |"
                  f" {r['tp']} | {r['zero']} | {r['sp']} | {r['ep']} |"
                  f" {r.get('backend', 'reference')} |"
                  f" {r['recompute']} |"
                  f" {r['schedule']} | {r['n_micro']} |"
                  f" {r['measured_ratio_stage0_over_last']:.2f} |"
                  f" {r['analytic_ratio_stage0_over_last']:.2f} |"
                  f" {'ok' if r['direction_ok'] else 'MISMATCH'} |")


if __name__ == "__main__":
    main()
