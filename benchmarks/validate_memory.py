"""Validation: the paper's analytical memory model vs XLA ground truth.

For each (arch, shape) the dry-run compiled, compare:

  * analytic state bytes  — repro.core.zero_memory under the ParallelConfig
    equivalent of the mesh (TP=model axis, DP=data axis, EP=min(model, E),
    ZeRO per the dry-run's --zero), params+optimizer (persistent inputs);
  * XLA argument bytes    — compiled.memory_analysis().argument_size_in_bytes
    minus the (analytically known) batch/cache input bytes;
  * analytic activations  — stage_activation_bytes (AC policy as lowered)
    vs XLA temp bytes (upper-bounded by temps: XLA temps also hold grads,
    logits and transient buffers — reported as a ratio, not an equality).

Writes benchmarks/artifacts/validation.json and prints a markdown table.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
DRY = os.path.join(ART, "dryrun")
GiB = 2 ** 30


def _batch_input_bytes(arch: str, shape: str) -> int:
    from repro.configs import get_spec
    from repro.core.notation import FamilyKind
    from repro.launch.specs import SHAPES
    spec = get_spec(arch)
    info = SHAPES[shape]
    n = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1) * 4
    if spec.family == FamilyKind.VLM and info["kind"] != "decode":
        n += info["batch"] * min(256, info["seq"] // 4) * spec.h * 2
    if spec.encoder is not None and info["kind"] != "decode":
        n += info["batch"] * spec.encoder.n_ctx * spec.h * 2
    return n


def _cache_bytes(arch: str, shape: str, n_chips: int) -> int:
    """Per-device cache input bytes for decode shapes — exact: walks the
    abstract cache and applies the SAME placement rule the dry-run sharded
    with (launch.specs.cache_placement)."""
    import jax
    from repro.configs import get_spec
    from repro.launch.specs import (SHAPES, cache_divisor, input_specs,
                                    spec_for_shape)
    from repro.models import build_model
    spec = spec_for_shape(get_spec(arch), shape)
    model = build_model(spec)
    ins = input_specs(get_spec(arch), shape, model=model)
    data_ax = n_chips // 16
    total = 0
    for leaf in jax.tree.leaves(ins["cache"]):
        import math
        n = math.prod(leaf.shape) if leaf.shape else 1
        total += (n * leaf.dtype.itemsize
                  // cache_divisor(leaf.shape, data_ax, 16))
    return total


def validate_one(arch: str, shape: str, mesh_tag: str = "pod16x16",
                 zero: str = "os+g") -> Optional[Dict[str, Any]]:
    from repro.configs import get_spec
    from repro.core import estimate_memory, zero_memory
    from repro.core.parallel_config import ZeROStage, RecomputePolicy
    from repro.launch.specs import SHAPES

    path = os.path.join(DRY, f"{arch}__{shape}__{mesh_tag}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "status": rec.get("status")}

    spec = get_spec(arch)
    info = SHAPES[shape]
    n_chips = 512 if "2x16" in mesh_tag else 256
    model_ax = 16
    data_ax = n_chips // 16
    ep = min(model_ax, spec.moe.n_routed) if spec.is_moe else 1
    from repro.core.parallel_config import ParallelConfig
    per_dev_batch = max(info["batch"] // data_ax, 1)
    cfg = ParallelConfig(dp=data_ax, tp=model_ax, pp=1, ep=ep, etp=1,
                         sp=True, zero=ZeROStage(zero),
                         recompute=RecomputePolicy.NONE,
                         micro_batch=per_dev_batch, seq_len=info["seq"])

    state = zero_memory(spec, cfg)
    if info["kind"] == "train":
        analytic_args = state.params + state.optimizer
    else:
        analytic_args = state.params
    xla_args = rec["memory"]["argument_size_in_bytes"]
    io_bytes = _batch_input_bytes(arch, shape) // max(data_ax, 1)
    if info["kind"] == "decode":
        io_bytes += _cache_bytes(arch, shape, n_chips)   # already per-device
    xla_state = max(xla_args - io_bytes, 1)

    out = {
        "arch": arch, "shape": shape, "status": "ok",
        "analytic_state_bytes": int(analytic_args),
        "xla_state_bytes": int(xla_state),
        "state_ratio": analytic_args / xla_state,
        "xla_temp_bytes": rec["memory"]["temp_size_in_bytes"],
    }
    if info["kind"] == "train":
        from repro.core import stage_activation_bytes
        act = stage_activation_bytes(spec, cfg)
        # XLA temps also hold fp32 grads + logits + transients
        grads = state.grads
        logits = per_dev_batch * info["seq"] * spec.vocab * 4 // model_ax
        out["analytic_act_bytes"] = int(act)
        out["analytic_temp_floor"] = int(act + grads + logits)
        out["temp_ratio"] = (act + grads + logits) / max(
            rec["memory"]["temp_size_in_bytes"], 1)
    return out


def validate_pp(arch: str, shape: str, pp: int,
                mesh_tag: str = "pod16x16",
                tag_suffix: str = "") -> Optional[Dict[str, Any]]:
    """Per-stage validation of a ``dryrun --pp N`` artifact: XLA's per-stage
    temp bytes (activations + grads + transients of the stage program, which
    holds the 1F1B in-flight microbatch count of that stage) against
    ``estimate_memory(spec, cfg, stage=s, in_flight_microbatches=...)``.

    The check is the paper's §6 in-flight-multiplier *direction*: stage 0
    (pp microbatches resident) must not be lighter than the last stage
    (1 resident) — in both the measured and the analytic column.  Run the
    dry-run with ``--n-micro >= pp``; with fewer microbatches every stage
    holds one in flight and the ratio degenerates to ~1."""
    path = os.path.join(
        DRY, f"{arch}__{shape}__{mesh_tag}__pp{pp}{tag_suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return _validate_pp_rec(rec)


def _validate_pp_rec(rec: Dict[str, Any]) -> Dict[str, Any]:
    arch, shape, pp = rec["arch"], rec["shape"], rec["pp"]
    mesh_tag = rec["mesh"]
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape, "pp": pp,
                "status": rec.get("status")}
    stages = rec["stages"]
    temps = [s["memory"].get("temp_size_in_bytes", 0) for s in stages]
    acts = [s["analytic"]["activations"] for s in stages]
    # The last stage's temps also hold the fp32 logits/CE buffers the
    # activation model deliberately excludes (same adjustment validate_one
    # makes) — subtract the analytically known size before comparing shape.
    from repro.configs import get_spec
    from repro.launch.specs import SHAPES
    spec = get_spec(arch)
    info = SHAPES[shape]
    model_ax = int(mesh_tag.split("x")[-1])
    n_micro = max(rec.get("options", {}).get("n_micro", 1), 1)
    n_chips = 512 if mesh_tag.startswith("pod2x") else 256
    data_ax = n_chips // model_ax // pp
    b_dev = max(info["batch"] // n_micro // max(data_ax, 1), 1)
    logits = b_dev * info["seq"] * spec.vocab * 4
    if spec.vocab % model_ax == 0:
        logits //= model_ax
    adj = list(temps)
    adj[-1] = max(adj[-1] - logits, 1)
    return {
        "arch": arch, "shape": shape, "pp": pp, "status": "ok",
        "n_micro": n_micro,
        "stages": [{
            "stage": s["stage"], "layers": s["layers"],
            "in_flight": s["in_flight"],
            "xla_temp_bytes": temps[i],
            "analytic_act_bytes": acts[i],
            "analytic_total_bytes": s["analytic"]["total"],
        } for i, s in enumerate(stages)],
        "measured_ratio_stage0_over_last": adj[0] / max(adj[-1], 1),
        "analytic_ratio_stage0_over_last": acts[0] / max(acts[-1], 1),
        "direction_ok": (adj[0] >= adj[-1]) and (acts[0] >= acts[-1]),
    }


def _pp_artifacts() -> List[Dict[str, Any]]:
    import glob
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, "*__pp*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if "pp" in rec:
            rows.append(_validate_pp_rec(rec))
    return rows


def main():
    from repro.configs import ASSIGNED
    from repro.launch.specs import SHAPES
    rows: List[Dict[str, Any]] = []
    for a in ASSIGNED:
        for s in SHAPES:
            r = validate_one(a, s)
            if r:
                rows.append(r)
    with open(os.path.join(ART, "validation.json"), "w") as f:
        json.dump(rows, f, indent=1)
    ok = [r for r in rows if r.get("status") == "ok"]
    print("| arch | shape | analytic state | XLA state | ratio |"
          " temp floor/XLA |")
    print("|---|---|---|---|---|---|")
    for r in ok:
        tr = r.get("temp_ratio")
        print(f"| {r['arch']} | {r['shape']} | "
              f"{r['analytic_state_bytes']/GiB:.2f} GiB | "
              f"{r['xla_state_bytes']/GiB:.2f} GiB | "
              f"{r['state_ratio']:.2f} | "
              + (f"{tr:.2f} |" if tr else "- |"))
    ratios = [r["state_ratio"] for r in ok]
    if ratios:
        print(f"\nstate-bytes agreement: median {np.median(ratios):.3f}, "
              f"[{min(ratios):.2f}, {max(ratios):.2f}] over {len(ok)} combos")

    pp_rows = _pp_artifacts()
    if pp_rows:
        with open(os.path.join(ART, "validation_pp.json"), "w") as f:
            json.dump(pp_rows, f, indent=1)
        print("\n## Per-stage 1F1B residency (dryrun --pp) vs "
              "estimate_memory(stage=s)")
        print("| arch | shape | pp | n_micro | stage0/last XLA (logits-adj) |"
              " stage0/last analytic act | direction |")
        print("|---|---|---|---|---|---|---|")
        for r in pp_rows:
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['pp']} | - | - | - |"
                      f" {r.get('status')} |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['pp']} |"
                  f" {r['n_micro']} |"
                  f" {r['measured_ratio_stage0_over_last']:.2f} |"
                  f" {r['analytic_ratio_stage0_over_last']:.2f} |"
                  f" {'ok' if r['direction_ok'] else 'MISMATCH'} |")


if __name__ == "__main__":
    main()
