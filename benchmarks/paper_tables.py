"""One benchmark per paper table: evaluates the analytical model, times it,
and checks the paper's published values (derived column)."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Tuple

from repro.configs import get_spec
from repro.core import (PAPER_CONFIG, RecomputePolicy, ZeROStage,
                        estimate_memory, table10, table4_stages, zero_table)
from repro.core.params import (device_params, table3_rows,
                               total_params_paper)

SPEC = get_spec("deepseek-v3")
GiB = 2 ** 30

Row = Tuple[str, float, str]


def _timeit(fn: Callable, n: int = 200) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def bench_table3_params() -> List[Row]:
    us = _timeit(lambda: total_params_paper(SPEC))
    total = total_params_paper(SPEC)
    rows = [("table3.total_params", us, f"{total}=={671_026_522_112}")]
    per = {r.layers: r.per_layer for r in table3_rows(SPEC)}
    rows.append(("table3.moe_layer_params", us,
                 f"{per['Layers 3 - 59']}=={11_507_288_064}"))
    return rows


def bench_table4_pp() -> List[Row]:
    us = _timeit(lambda: table4_stages(SPEC, 16))
    st = table4_stages(SPEC, 16)
    return [
        ("table4.stage1_params", us, f"{st[1].params}=={46_029_152_256}"),
        ("table4.stage1_gib", us,
         f"{st[1].params * 2 / GiB:.1f}~=86"),
        ("table4.n_stages", us, f"{len(st)}==16"),
    ]


def bench_table6_device() -> List[Row]:
    us = _timeit(lambda: device_params(SPEC, PAPER_CONFIG))
    d = device_params(SPEC, PAPER_CONFIG)
    return [
        ("table6.total_per_device", us, f"{d.total}=={6_250_364_928}"),
        ("table6.moe_bytes", us, f"{d.expert * 2}=={11_641_290_752}"),
        ("table6.non_moe_bytes", us, f"{d.non_expert * 2}=={859_439_104}"),
    ]


def bench_table8_zero() -> List[Row]:
    us = _timeit(lambda: zero_table(SPEC, PAPER_CONFIG))
    t = zero_table(SPEC, PAPER_CONFIG)
    return [
        ("table8.none_pgo_gib", us, f"{t['none'].total / GiB:.2f}~=81.5"),
        ("table8.os_opt_gib", us,
         f"{t['os'].optimizer / GiB:.2f}==5.52"),
        ("table8.os+g_grads_gib", us,
         f"{t['os+g'].grads / GiB:.2f}==2.76"),
        ("table8.os+g+p_params_gib", us,
         f"{t['os+g+params'].params / GiB:.2f}==1.38"),
    ]


def bench_table10_activations() -> List[Row]:
    us = _timeit(lambda: table10(SPEC, PAPER_CONFIG))
    t = table10(SPEC, PAPER_CONFIG)
    b, s, h, nr = 1, 4096, 7168, 8
    return [
        ("table10.ac_none_total", us, f"{t['none']['Total']}"),
        ("table10.ac_full_total", us,
         f"{t['full']['Total']}=={8 * b * s * h + 8 * b * s * nr}"),
        ("table10.mla_none_gib", us, f"{t['none']['MLA'] / GiB:.2f}~=21.59"),
    ]


def bench_section6_buffers() -> List[Row]:
    us = _timeit(lambda: estimate_memory(SPEC, PAPER_CONFIG))
    e = estimate_memory(SPEC, PAPER_CONFIG)
    frac = e.fragmentation / max(e.total - e.fragmentation, 1)
    return [
        ("sec6.comm_buffer_gib", us,
         f"{e.comm_buffers / GiB:.2f}in[0.8,2.0]"),
        ("sec6.fragmentation_frac", us, f"{frac:.3f}in[0.05,0.30]"),
        ("sec6.full_estimate_gib", us, f"{e.total / GiB:.2f}"),
    ]


def bench_fp8_whatif() -> List[Row]:
    """Beyond-paper: the paper scopes FP8 out (§1.2); the model supports it
    as a dtype policy — what Table 8 would look like at 1-byte weights."""
    from repro.core import FP8_POLICY
    cfg = dataclasses.replace(PAPER_CONFIG, dtype=FP8_POLICY)
    us = _timeit(lambda: zero_table(SPEC, cfg))
    t = zero_table(SPEC, cfg)
    bf16 = zero_table(SPEC, PAPER_CONFIG)
    return [
        ("fp8.params_gib_vs_bf16", us,
         f"{t['none'].params / GiB:.2f}vs{bf16['none'].params / GiB:.2f}"),
        ("fp8.os+g+p_total_gib", us,
         f"{t['os+g+params'].total / GiB:.2f}"),
    ]


def bench_planner() -> List[Row]:
    """Beyond-paper: config search (what the analysis is FOR)."""
    from repro.core import plan
    run = lambda: plan(SPEC, world_size=1024, hbm_bytes=64 * GiB,
                       seq_len=4096, top_k=1)
    us = _timeit(run, n=3)
    entries = run()
    best = entries[0].cfg.describe() if entries else "none"
    return [("planner.best_1024x64GiB", us, best.replace(",", ";"))]


ALL = [bench_table3_params, bench_table4_pp, bench_table6_device,
       bench_table8_zero, bench_table10_activations, bench_section6_buffers,
       bench_fp8_whatif, bench_planner]
