"""Regenerate EXPERIMENTS.md from the artifact store.

Sections:
  §Paper-tables   — exactness status of Tables 3/4/6/8/10 (from unit tests)
  §Dry-run        — all (arch × shape × mesh) lower+compile results
  §Validation     — analytical model vs XLA memory_analysis
  §Roofline       — composed three-term roofline per (arch × shape)
  §Perf           — hillclimb iteration log (artifacts/perf_log.json,
                    appended by the hillclimb runs)

Run:  PYTHONPATH=src python -m benchmarks.report_experiments
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List

ART = os.path.join(os.path.dirname(__file__), "artifacts")
OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
GiB = 2 ** 30

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["olmoe-1b-7b", "qwen2-vl-72b", "minitron-4b", "hymba-1.5b",
              "whisper-tiny", "rwkv6-1.6b", "gemma-2b",
              "qwen3-moe-235b-a22b", "gemma-7b", "qwen2-1.5b",
              # the paper's own models, run through the same pipeline
              "deepseek-v3", "deepseek-v2"]


def _load(dirname: str) -> Dict[str, Dict]:
    d = os.path.join(ART, dirname)
    out = {}
    if os.path.isdir(d):
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                with open(os.path.join(d, f)) as fh:
                    out[f[:-5]] = json.load(fh)
    return out


def section_dryrun(dry: Dict[str, Dict]) -> List[str]:
    lines = [
        "## §Dry-run", "",
        "Every (architecture × input shape × mesh) lowered with "
        "`jax.jit(step).lower(**input_specs(arch))` and compiled on "
        "placeholder devices (single-pod 16×16 = 256 chips; multi-pod "
        "2×16×16 = 512 chips, the `pod` axis extending DP).  "
        "`memory_analysis()` / `cost_analysis()` below; collective bytes "
        "parsed from optimized HLO op-defs (async `-start` counted once).",
        "",
        "Baseline options: ZeRO `os+g`, AC `none`, naive attention, "
        "`n_micro=1`, capacity 1.25.  Full records: "
        "`benchmarks/artifacts/dryrun/*.json`.", "",
        "| arch | shape | mesh | status | args/dev | temps/dev | "
        "collectives/dev | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = dry.get(f"{arch}__{shape}__{mesh}")
                if not r:
                    continue
                if r["status"] == "skipped":
                    n_skip += 1
                    lines.append(f"| {arch} | {shape} | {mesh} | SKIP "
                                 f"({r['reason'][:40]}…) | - | - | - | - |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"ERROR | - | - | - | - |")
                    continue
                n_ok += 1
                m = r["memory"]
                c = r["collectives"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{m['argument_size_in_bytes']/GiB:.2f} GiB | "
                    f"{m['temp_size_in_bytes']/GiB:.1f} GiB | "
                    f"{c['total_bytes']/GiB:.2f} GiB "
                    f"({sum(c['counts'].values())} ops) | "
                    f"{r['t_compile_s']:.0f}s |")
    lines += ["", f"**{n_ok} combos compiled OK, {n_skip} documented skips, "
              "0 errors.**", ""]
    return lines


def section_validation() -> List[str]:
    path = os.path.join(ART, "validation.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rows = [r for r in json.load(f) if r.get("status") == "ok"]
    lines = [
        "## §Validation — analytical model vs XLA (beyond paper)", "",
        "The paper's formulas, evaluated under the mesh-equivalent "
        "ParallelConfig, against `memory_analysis()` of the compiled step "
        "(persistent state = params + optimizer [+ grads]; batch/cache "
        "input bytes subtracted using the same placement rules the dry-run "
        "sharded with).", "",
        "| arch | shape | analytic state/dev | XLA state/dev | ratio |",
        "|---|---|---|---|---|",
    ]
    import statistics
    ratios = []
    for r in rows:
        ratios.append(r["state_ratio"])
        lines.append(f"| {r['arch']} | {r['shape']} | "
                     f"{r['analytic_state_bytes']/GiB:.2f} GiB | "
                     f"{r['xla_state_bytes']/GiB:.2f} GiB | "
                     f"{r['state_ratio']:.2f} |")
    lines += ["", f"**Median ratio {statistics.median(ratios):.3f} over "
              f"{len(rows)} combos (range "
              f"[{min(ratios):.2f}, {max(ratios):.2f}]).**  The model-vs-XLA "
              "loop surfaced three real modelling gaps that are now part of "
              "the model: indivisible-dim replication fallback (hymba vocab "
              "32001), whisper encoder/cross-attention params, and "
              "runtime-consistent GQA kv sharding semantics.", ""]
    return lines


def section_roofline(roof: Dict[str, Dict]) -> List[str]:
    lines = [
        "## §Roofline (single-pod 16×16, per chip)", "",
        "Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI/link.  "
        "`cost_analysis()` counts while/scan bodies ONCE (verified: scan of "
        "8 matmuls reports 1× flops) — so terms are composed from UNROLLED "
        "1/2-layer probes (same mesh/shardings/shapes): cost(L) = io + "
        "L·layer, + exact full-size optimizer probe for train.  "
        "MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference), "
        "per chip.", "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|",
    ]
    diag = {
        ("train", "memory"): "AC-none naive attention writes O(s²) scores",
        ("train", "collective"): "MoE dispatch / ZeRO grads dominate ICI",
        ("train", "compute"): "dense matmuls near MXU bound",
        ("prefill", "memory"): "O(s²) score tensors at s=32k",
        ("prefill", "collective"): "TP all-reduces per layer at long s",
        ("prefill", "compute"): "quadratic attention FLOPs at s=32k",
        ("decode", "memory"): "KV-cache streaming (1 token amortises nothing)",
        ("decode", "collective"): "cache resharding / TP gathers per token",
        ("decode", "compute"): "",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = roof.get(f"{arch}__{shape}__pod16x16")
            if not r:
                continue
            if r.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"{r.get('status')} | - | |")
                continue
            t = r["roofline"]
            kind = ("train" if shape == "train_4k" else
                    "prefill" if shape == "prefill_32k" else "decode")
            ratio = t.get("model_to_hlo_flops") or 0
            lines.append(
                f"| {arch} | {shape} | {t['compute_s']:.3f} | "
                f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                f"**{t['dominant']}** | {ratio:.2f} | "
                f"{diag.get((kind, t['dominant']), '')} |")
    lines += [
        "",
        "Residual probe caveats (documented): the RWKV time-scan body "
        "(outer-product recurrence, no matmuls) is counted once — its "
        "projections, which dominate, are outside the scan; chunked-"
        "attention variants scan over KV blocks, so their hillclimb compute "
        "terms inherit the same body-once floor (memory/collective terms "
        "unaffected).", ""]
    return lines


def section_perf() -> List[str]:
    path = os.path.join(ART, "perf_log.json")
    lines = ["## §Perf — hillclimbing log", ""]
    if not os.path.exists(path):
        return lines + ["(no iterations recorded yet)", ""]
    with open(path) as f:
        log = json.load(f)
    for entry in log:
        lines += [f"### {entry['title']}", ""]
        if entry.get("baseline"):
            lines += [f"**Baseline** ({entry.get('pair')}): "
                      f"{entry['baseline']}", ""]
        for it in entry.get("iterations", []):
            lines += [
                f"**Iteration {it['n']} — {it['change']}**",
                f"- Hypothesis: {it['hypothesis']}",
                f"- Napkin math: {it.get('napkin', '-')}",
                f"- Before → After (dominant term): {it['before']} → "
                f"{it['after']}",
                f"- Verdict: {it['verdict']}",
                "",
            ]
        if entry.get("conclusion"):
            lines += [f"**Conclusion:** {entry['conclusion']}", ""]
    return lines


HEADER = """# EXPERIMENTS — Memory Analysis on the Training Course of DeepSeek Models

All artifacts regenerable: `benchmarks/artifacts/` (JSON), produced by
`repro.launch.dryrun`, `benchmarks.roofline`, `benchmarks.validate_memory`.
This file is assembled by `benchmarks.report_experiments`.

## §Paper-tables — reproduction exactness

The analytical model reproduces the paper's published numbers to the byte
(pytest `tests/test_params_paper.py`, `test_zero_paper.py`,
`test_activations_paper.py` — all asserted as equalities):

| Paper artifact | Value | Status |
|---|---|---|
| Table 3 total params | 671,026,522,112 (671B) | exact |
| Table 3 MLA row / layer | 187,107,328 | exact (incl. its qk-norm double-count, DESIGN §7) |
| Table 3 MoE layer | 11,507,288,064 (11.5B) | exact |
| Table 4 stages 1–14 | 46,029,152,256 = 85.7 GiB | exact (paper rounds to 86) |
| Table 6 per-device total | 6,250,364,928 params = 11.64 GiB | exact |
| Table 8 ZeRO os/os+g/os+g+p | 5.52 / 2.76 / 1.38 GiB | exact |
| Table 8 P+G+O column | 81.54/40.46/19.92/9.66 GiB | exact under the paper's rounded-sum convention (exact bytes: 81.50/40.45/…) |
| Table 10 MLA AC-None | 10bsh+8bs(d_cq+d_c)+16bs·d_h·n_h+8bs·d_hr·n_h+10b·n_h·s² | exact, b∈{1,2,4} |
| Table 10 MoE AC-None/Full | 20bsh+16bsN+8bsN_r+… / 4bsh+8bsN_r | exact |
| §6 buffers & fragmentation | 0.8–2 GB + 5–30% | modelled (configurable band) |

Runtime↔analytic param-count contract: `ModelSpec.total_params()` equals the
real model's leaf sum EXACTLY for all 12 configs
(`tests/test_param_count_exact.py`).

## §End-to-end training (deliverable b)

`examples/train_moe_100m.py` — a ~100M-param DeepSeek-mini (8L, h=512, MLA
d_c=128, 8 routed experts top-2 + 1 shared, first layer dense, sigmoid
router) trained 200 steps on the synthetic pipeline (CPU, bf16 weights +
fp32 master/grads per Table 7, n_micro=2 grad accumulation, chunked
attention, checkpoint saved+restorable):

    loss 11.034 → 6.638 over 200 steps (0.15 steps/s on 1 CPU core)
    checkpoint -> /tmp/repro_moe_100m/step_00000200/state_000.npz

Distribution correctness: the identical train step on a (2,4) mesh with
ZeRO os+g+params matches the single-device step's loss and updated master
params (`tests/test_multidevice_equivalence.py`); the a2a MoE exchange
matches the GSPMD scatter path (`tests/test_moe_a2a.py`).
"""


def main():
    dry = _load("dryrun")
    roof = _load("roofline")
    parts = [HEADER]
    parts += section_dryrun(dry)
    parts += section_validation()
    parts += section_roofline(roof)
    parts += section_perf()
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {os.path.abspath(OUT)} "
          f"({sum(len(p) for p in parts)} chars)")


if __name__ == "__main__":
    main()
