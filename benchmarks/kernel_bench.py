"""Kernel micro-benchmarks: pallas-vs-reference rows on the step-bench grid.

Every op the backend dispatcher (``repro.models.backend``) can route —
rmsnorm, flash attention, grouped-mlp gmm — is timed twice per shape:

* ``<op>.reference.<shape>`` — the jnp oracle (``repro.kernels.ref`` /
  the model-stack twin), i.e. what ``ModelOptions(backend="reference")``
  executes;
* ``<op>.pallas.<shape>``    — the Pallas kernel via ``repro.kernels.ops``
  (interpret mode off-TPU).

Shapes are aligned to ``benchmarks/step_bench.py``'s smoke cell
(qwen2-1.5b smoke spec, batch 8, seq 128, tp 2) so a kernel row's shape is
exactly what one executor shard feeds the kernel in the matching
BENCH_step.json cell — flash sees n_h/tp heads, gmm the (E, C, h) local
dispatch buffer.  ``--smoke`` keeps only those aligned shapes.

Wall-clock on CPU is NOT the TPU story: interpret-mode pallas lowers to
pure-jax emulation and is *expected* to be slower than the XLA-fused
reference there.  The ``--check`` gate is therefore host-aware:

* on TPU, pallas rmsnorm/flash must beat (or tie within ``--band``) the
  reference rows;
* off-TPU, the gate asserts row presence/finiteness, newest-wins dedupe,
  and the *analytic* direction instead — the flash row's derived resident
  act bytes must undercut the naive row's 5·b·n_h·s² (the claim the
  memory model prices; wall clock is not gated).

Rows land in BENCH_kernels.json, deduped newest-wins on ``name`` like
BENCH_step.json.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Any, Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.train.timing import merge_rows, time_callable

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_kernels.json")

# The step-bench smoke cell (benchmarks/step_bench.py: ARCH/BATCH/SEQ and
# the pp2·dp2·tp2 grid rows) seen from ONE executor shard:
#   qwen2-1.5b smoke → h=256, n_h=4, d_head=64; tp=2 → 2 heads/shard;
#   batch 8 over dp2 × n_micro4 → micro_batch 1; seq 128.
STEP_B, STEP_S, STEP_H = 1, 128, 256
STEP_NH_SHARD, STEP_D = 2, 64
# qwen2-moe-a2.7b smoke expert geometry: E=4 experts, h=256, d_ff=128;
# capacity C = S·n_active/E at capacity_factor 1 → 64 rows/expert.
STEP_E, STEP_C, STEP_DFF = 4, 64, 128


def _time(fn, *args, n=5) -> float:
    """Median-of-``n`` µs via the shared harness timer (warmup outside the
    timed windows, block inside each)."""
    return time_callable(fn, *args, iters=n, warmup=1).median_us


def _row(name: str, us: float, derived: str) -> Dict[str, Any]:
    return {"name": name, "us_per_call": us, "derived": derived,
            "timer": "median_of_5_blocked",
            "host": jax.default_backend()}


def bench_rmsnorm(smoke: bool) -> List[Dict[str, Any]]:
    shapes = [(STEP_B * STEP_S, STEP_H)]
    if not smoke:
        shapes.append((4096, 1024))
    rows = []
    for (r, h) in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (r, h), jnp.float32)
        s = jnp.ones((h,), jnp.float32)
        vmem_kib = (min(256, r) * h * 4 * 2) / 1024
        derived = f"tile_vmem={vmem_kib:.0f}KiB ai=O(1)"
        rows.append(_row(f"rmsnorm.reference.{r}x{h}",
                         _time(lambda: ref.rmsnorm_ref(x, s)), derived))
        rows.append(_row(f"rmsnorm.pallas.{r}x{h}",
                         _time(lambda: ops.rmsnorm(x, s)), derived))
    return rows


def bench_flash(smoke: bool) -> List[Dict[str, Any]]:
    shapes = [(STEP_B, STEP_S, STEP_NH_SHARD, STEP_D)]
    if not smoke:
        shapes.append((1, 1024, 4, 128))
    rows = []
    for (b, s, nh, d) in shapes:
        scale = d ** -0.5
        q = jax.random.normal(jax.random.PRNGKey(1), (b, s, nh, d),
                              jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(2), (b, s, nh, d),
                              jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(3), (b, s, nh, d),
                              jnp.float32)
        shape = f"b{b}s{s}h{nh}d{d}"
        naive_bytes = 5 * b * nh * s * s
        rows.append(_row(
            f"attn.reference.{shape}",
            _time(lambda: ref.flash_attention_ref(q, k, v, scale=scale)),
            f"act_bytes={naive_bytes}"))
        bq = min(128, s)
        tile = (bq * d * 4 * 4) / 1024
        # flash keeps only the (b, nh, s) row stats + output resident
        flash_bytes = 2 * b * nh * s * (d + 2)
        rows.append(_row(
            f"attn.pallas.{shape}",
            _time(lambda: ops.flash_attention(q, k, v, scale=scale,
                                              block_q=bq, block_k=bq)),
            f"act_bytes={flash_bytes} tile_vmem={tile:.0f}KiB"))
        from repro.models.attention import chunked_attention
        rows.append(_row(
            f"attn.chunked.{shape}",
            _time(lambda: chunked_attention(q, k, v, scale, block=bq)),
            f"act_bytes={naive_bytes}"))   # scan residuals stay O(s²) under AD
    return rows


def bench_gmm(smoke: bool) -> List[Dict[str, Any]]:
    shapes = [(STEP_E, STEP_C, STEP_H, STEP_DFF)]
    if not smoke:
        shapes.append((8, 128, 256, 512))
    rows = []
    for (E, C, K, N) in shapes:
        x = jax.random.normal(jax.random.PRNGKey(4), (E * C, K), jnp.float32)
        rhs = jax.random.normal(jax.random.PRNGKey(5), (E, K, N), jnp.float32)
        bm = 128 if C % 128 == 0 else C
        emap = jnp.repeat(jnp.arange(E, dtype=jnp.int32), C // bm)
        shape = f"E{E}C{C}k{K}n{N}"
        mxu = 2 * bm * K * N
        moved = (bm * K + K * N + bm * N) * 4
        derived = f"tile_ai={mxu / moved:.0f}flops/B"
        rows.append(_row(
            f"gmm.reference.{shape}",
            _time(lambda: jnp.einsum("eck,ekn->ecn",
                                     x.reshape(E, C, K), rhs)), derived))
        rows.append(_row(
            f"gmm.pallas.{shape}",
            _time(lambda: ops.gmm(x, rhs, emap, block_m=bm,
                                  block_n=128 if N % 128 == 0 else N)),
            derived))
    return rows


def _act_bytes(derived: str) -> float:
    for tok in derived.split():
        if tok.startswith("act_bytes="):
            return float(tok.split("=", 1)[1])
    return math.nan


def check_rows(rows: List[Dict[str, Any]], *, band: float = 0.25) -> List[str]:
    """Host-aware CI gate over the artifact rows (see module docstring).
    Returns violation messages (empty == pass)."""
    bad: List[str] = []
    by_name = {r["name"]: r for r in rows}
    if len(by_name) != len(rows):
        from collections import Counter
        dup = [n for n, c in Counter(r["name"] for r in rows).items() if c > 1]
        bad.append(f"duplicate rows after dedupe: {dup}")
    for r in rows:
        us = r.get("us_per_call")
        if us is None or not math.isfinite(us) or us <= 0:
            bad.append(f"{r.get('name')}: non-finite us_per_call {us}")
    pallas = [n for n in by_name if ".pallas." in n]
    for op in ("rmsnorm", "attn", "gmm"):
        if not any(n.startswith(op + ".pallas.") for n in pallas):
            bad.append(f"no {op}.pallas.* row in the artifact")
        if not any(n.startswith(op + ".reference.") for n in by_name):
            bad.append(f"no {op}.reference.* row in the artifact")
    on_tpu = any(r.get("host") == "tpu" for r in rows)
    if on_tpu:
        for n in pallas:
            twin = n.replace(".pallas.", ".reference.")
            if twin not in by_name or n.startswith("gmm."):
                continue           # gmm's einsum twin fuses differently; no gate
            pu, ru = by_name[n]["us_per_call"], by_name[twin]["us_per_call"]
            if pu > ru * (1 + band):
                bad.append(f"{n}: {pu:.1f}us exceeds {twin} {ru:.1f}us "
                           f"beyond the {band:.0%} band on TPU")
    else:
        # interpret-mode host: wall clock is meaningless for the kernels;
        # gate the analytic direction the memory model prices instead
        for n in by_name:
            if not n.startswith("attn.pallas."):
                continue
            twin = n.replace(".pallas.", ".reference.")
            if twin not in by_name:
                continue
            fb = _act_bytes(by_name[n]["derived"])
            nb = _act_bytes(by_name[twin]["derived"])
            if not (fb < nb):
                bad.append(f"{n}: derived act_bytes {fb} not below "
                           f"{twin}'s {nb} (flash must drop the s² term)")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="step-grid-aligned shapes only (CI tier)")
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--check", action="store_true",
                    help="host-aware gate over the artifact (no new "
                         "measurements): timing ordering on TPU, "
                         "presence/finiteness + analytic act-bytes "
                         "direction off-TPU")
    ap.add_argument("--band", type=float, default=0.25,
                    help="relative tie band for the TPU timing gate")
    args = ap.parse_args(argv)

    if args.check:
        if not os.path.exists(args.out):
            print(f"no artifact at {args.out}; run the bench first",
                  file=sys.stderr)
            return 2
        with open(args.out) as f:
            rows = json.load(f)
        bad = check_rows(rows, band=args.band)
        for msg in bad:
            print(f"KERNEL BENCH VIOLATION: {msg}", file=sys.stderr)
        print(f"kernel bench check: {len(rows)} rows, {len(bad)} violations")
        return 1 if bad else 0

    rows: List[Dict[str, Any]] = []
    for fn in (bench_rmsnorm, bench_flash, bench_gmm):
        for row in fn(args.smoke):
            rows.append(row)
            print(f"{row['name']},{row['us_per_call']:.2f},{row['derived']}")
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(merge_rows(existing, rows, ("name",)), f, indent=1)
        f.write("\n")
    print(f"wrote {len(rows)} rows -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
