"""Kernel micro-benchmarks (interpret-mode correctness + jnp-twin timing).

Wall-clock on CPU is NOT the TPU story — the derived column therefore also
reports the analytic VMEM working set and arithmetic intensity per tile,
which is what the TPU roofline consumes.  The jnp twin (chunked attention /
einsum gmm) is timed as the XLA-fused reference the Pallas kernel must beat
on real hardware.
"""

from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.train.timing import merge_rows, time_callable

Row = Tuple[str, float, str]

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_kernels.json")


def _time(fn, *args, n=5) -> float:
    """Median-of-``n`` µs via the shared harness timer (warmup outside the
    timed windows, block inside each).  The old inline loop here reported a
    mean over one blocked region — a single scheduler hiccup skewed it and
    async dispatch of call k could leak into window k+1's sample."""
    return time_callable(fn, *args, iters=n, warmup=1).median_us


def bench_rmsnorm() -> List[Row]:
    rows = []
    for (r, h) in [(1024, 2048), (4096, 1024)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (r, h), jnp.float32)
        s = jnp.ones((h,), jnp.float32)
        us_ref = _time(lambda: ref.rmsnorm_ref(x, s))
        vmem_kib = (256 * h * 4 * 2) / 1024
        rows.append((f"rmsnorm.jnp_ref.{r}x{h}", us_ref,
                     f"tile_vmem={vmem_kib:.0f}KiB ai=O(1)"))
    return rows


def bench_flash() -> List[Row]:
    rows = []
    b, s, nh, d = 1, 1024, 4, 128
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, nh, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, nh, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, nh, d), jnp.float32)
    us_naive = _time(lambda: ref.flash_attention_ref(q, k, v, scale=0.088))
    from repro.models.attention import chunked_attention
    us_chunk = _time(lambda: chunked_attention(q, k, v, 0.088, block=128))
    # per-tile VMEM: q(128xd)+k(128xd)+v(128xd)+acc ≈
    tile = (128 * d * 4 * 4) / 1024
    ai = (2 * 128 * 128 * d) / ((128 * d * 2 + 128 * d * 2) * 2)
    rows.append((f"attn.naive_ref.s{s}", us_naive,
                 f"act_bytes={5 * b * nh * s * s * 2}"))
    rows.append((f"attn.chunked_jnp.s{s}", us_chunk,
                 f"tile_vmem={tile:.0f}KiB ai={ai:.0f}flops/B"))
    return rows


def bench_gmm() -> List[Row]:
    from repro.kernels.moe_gmm import pad_groups
    E, K, N, bm = 8, 256, 512, 64
    sizes = np.full(E, 128)
    x = jax.random.normal(jax.random.PRNGKey(4), (int(sizes.sum()), K),
                          jnp.float32)
    rhs = jax.random.normal(jax.random.PRNGKey(5), (E, K, N), jnp.float32)
    lhs, emap, _ = pad_groups(x, sizes, bm)
    us_einsum = _time(lambda: jnp.einsum(
        "etk,ekn->etn", lhs.reshape(E, -1, K), rhs))
    mxu = 2 * bm * K * N
    moved = (bm * K + K * N + bm * N) * 4
    rows = [(f"gmm.einsum_ref.E{E}", us_einsum,
             f"tile_ai={mxu / moved:.0f}flops/B")]
    return rows


ALL = [bench_rmsnorm, bench_flash, bench_gmm]


def main(out_path: str = ARTIFACT) -> int:
    """Run every kernel bench and land the rows in BENCH_kernels.json —
    same row schema as the CSV (name, µs, derived) plus the timing
    provenance, deduped newest-wins on ``name`` like BENCH_step.json."""
    rows = []
    for fn in ALL:
        for name, us, derived in fn():
            rows.append({"name": name, "us_per_call": us, "derived": derived,
                         "timer": "median_of_5_blocked"})
            print(f"{name},{us:.2f},{derived}")
    existing = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            existing = json.load(f)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(merge_rows(existing, rows, ("name",)), f, indent=1)
        f.write("\n")
    print(f"wrote {len(rows)} rows -> {out_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
