"""Roofline analysis (deliverable g).

Terms per (arch × shape) on the single-pod mesh, per chip:

  compute_s    = HLO_FLOPs / 197e12           (bf16 peak per v5e chip)
  memory_s     = HLO_bytes / 819e9            (HBM bandwidth)
  collective_s = collective_bytes / 50e9      (ICI link bandwidth)

Sourcing caveat (measured, see EXPERIMENTS.md §Roofline): XLA's
``cost_analysis()`` counts a ``while``/scan body ONCE regardless of trip
count, so the full scan-over-layers module under-reports by ~L×.  We
therefore compile small UNROLLED probe modules (1 and 2 layers, same mesh,
same shardings, same per-microbatch shapes) and compose:

  cost(L) = io + L · layer        (linear in L at fixed batch)

solving {io, layer} from the two probes — three probes when two layer kinds
exist (dense+MoE, or encoder+decoder).  Optimizer cost is probed separately
(adamw on the full stacked state, no scan → exact).  Composed totals are
cross-checked against the full-module numbers (which bound from below) and
against analytic 6·N·D MODEL_FLOPS.

Known residual undercounts (documented, small): the RWKV time-scan body
(outer-product recurrence, no matmuls — projections dominate) and chunked-
attention KV-block scans in hillclimb variants.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (TPU v5e-class)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
GiB = 2 ** 30

ART = os.path.join(os.path.dirname(__file__), "artifacts")
ROOF_DIR = os.path.join(ART, "roofline")
DRY_DIR = os.path.join(ART, "dryrun")


# ---------------------------------------------------------------------------
# probe machinery (imports jax lazily — caller must set XLA_FLAGS first)
# ---------------------------------------------------------------------------

def _probe_variants(spec):
    """[(tag, spec_variant, coeffs)] with cost = Σ coeffs[k]·unknown[k];
    unknowns ordered ('io', kinds...)."""
    import dataclasses as dc
    if spec.encoder is not None:
        enc = spec.encoder
        mk = lambda d, e: dc.replace(spec, n_layers=d,
                                     encoder=dc.replace(enc, n_layers=e))
        return (["io", "dec", "enc"],
                [("d1e1", mk(1, 1), {"io": 1, "dec": 1, "enc": 1}),
                 ("d2e1", mk(2, 1), {"io": 1, "dec": 2, "enc": 1}),
                 ("d1e2", mk(1, 2), {"io": 1, "dec": 1, "enc": 2})],
                {"dec": spec.n_layers, "enc": enc.n_layers})
    if spec.is_moe and spec.moe.first_k_dense > 0:
        import dataclasses as dc
        moe0 = dc.replace(spec.moe, first_k_dense=0)
        moe1 = dc.replace(spec.moe, first_k_dense=1)
        return (["io", "dense", "moe"],
                [("dense1", dc.replace(spec, n_layers=1, moe=moe1),
                  {"io": 1, "dense": 1}),
                 ("moe1", dc.replace(spec, n_layers=1, moe=moe0),
                  {"io": 1, "moe": 1}),
                 ("moe2", dc.replace(spec, n_layers=2, moe=moe0),
                  {"io": 1, "moe": 2})],
                {"dense": spec.n_dense_layers(), "moe": spec.n_moe_layers()})
    if spec.is_moe:
        import dataclasses as dc
        return (["io", "moe"],
                [("moe1", dc.replace(spec, n_layers=1), {"io": 1, "moe": 1}),
                 ("moe2", dc.replace(spec, n_layers=2), {"io": 1, "moe": 2})],
                {"moe": spec.n_layers})
    import dataclasses as dc
    return (["io", "layer"],
            [("l1", dc.replace(spec, n_layers=1), {"io": 1, "layer": 1}),
             ("l2", dc.replace(spec, n_layers=2), {"io": 1, "layer": 2})],
            {"layer": spec.n_layers})


def _solve(unknowns, rows: List[Tuple[Dict[str, int], Dict[str, float]]]
           ) -> Dict[str, Dict[str, float]]:
    """Solve per-metric linear systems (tiny, exact via numpy lstsq)."""
    import numpy as np
    metrics = rows[0][1].keys()
    A = np.array([[c.get(u, 0) for u in unknowns] for c, _ in rows], float)
    out = {u: {} for u in unknowns}
    for m in metrics:
        b = np.array([v[m] for _, v in rows], float)
        x, *_ = np.linalg.lstsq(A, b, rcond=None)
        for u, val in zip(unknowns, x):
            out[u][m] = float(max(val, 0.0))
    return out


def _grad_probe(arch, shape_name, vspec, mesh, n_micro, build_kw):
    """Compile loss+grad (no optimizer) at the per-microbatch shape."""
    import jax
    from repro.core.parallel_config import RecomputePolicy, ZeROStage
    from repro.launch.dryrun import collective_bytes, _fake_state
    from repro.launch.specs import SHAPES, batch_shardings, batch_specs, \
        spec_for_shape
    from repro.models import build_model
    from repro.models.transformer import ModelOptions
    from repro.parallel.axes import axis_rules
    from repro.parallel.sharding import grad_shardings, state_shardings

    spec = spec_for_shape(vspec, shape_name)
    info = SHAPES[shape_name]
    opts = ModelOptions(attn_impl=build_kw.get("attn_impl", "naive"),
                        recompute=RecomputePolicy(
                            build_kw.get("recompute", "none")),
                        capacity_factor=build_kw.get("capacity_factor", 1.25),
                        scan_layers=False,
                        moe_impl=build_kw.get("moe_impl", "scatter"),
                        backend=build_kw.get("backend", "reference"))
    model = build_model(spec, opts)
    z = ZeROStage(build_kw.get("zero", "os+g"))
    micro_b = max(info["batch"] // n_micro, 1)
    batch = batch_specs(spec, micro_b, info["seq"])
    abstract_params = model.abstract_params()

    def grad_step(params, b):
        (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(params, b)
        return g, loss

    with axis_rules(mesh):
        p_sh = state_shardings(_fake_state(abstract_params), mesh, z).params
        g_sh = grad_shardings(abstract_params, mesh, z)
        b_sh = batch_shardings(batch, mesh)
        lowered = jax.jit(grad_step, in_shardings=(p_sh, b_sh),
                          out_shardings=(g_sh, None)
                          ).lower(abstract_params, batch)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"])}


def _opt_probe(arch, mesh, build_kw):
    """Compile adamw_update alone on the FULL stacked state (no scan —
    exact cost)."""
    import jax
    from repro.core.parallel_config import ZeROStage
    from repro.launch.dryrun import collective_bytes
    from repro.configs import get_spec
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig, adamw_update, init_train_state
    from repro.parallel.axes import axis_rules
    from repro.parallel.sharding import grad_shardings, state_shardings

    spec = get_spec(arch)
    model = build_model(spec)
    z = ZeROStage(build_kw.get("zero", "os+g"))
    abstract_state = jax.eval_shape(init_train_state, model.abstract_params())
    abstract_grads = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, "float32"),
        model.abstract_params())
    cfg = AdamWConfig()

    def step(state, grads):
        new_state, _ = adamw_update(state, grads, cfg)
        return new_state

    with axis_rules(mesh):
        st_sh = state_shardings(abstract_state, mesh, z)
        g_sh = grad_shardings(model.abstract_params(), mesh, z)
        compiled = jax.jit(step, in_shardings=(st_sh, g_sh),
                           out_shardings=st_sh
                           ).lower(abstract_state, abstract_grads).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"])}


def probe_costs(arch: str, shape_name: str, *, multi_pod: bool = False,
                n_micro: int = 1, mesh_shape=None,
                **build_kw) -> Dict[str, Any]:
    """Compose per-device (flops, bytes, collective bytes) for the full
    architecture from unrolled 1/2-layer probe compiles.

    Train: cost = n_micro · (io + Σ count_k·layer_k)  [grad probes]
                  + optimizer [full-size probe, exact].
    Prefill/decode: cost = io + Σ count_k·layer_k     [step probes].
    """
    from repro.configs import get_spec
    from repro.launch.dryrun import build_step, collective_bytes, \
        lower_and_compile
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import SHAPES

    spec = get_spec(arch)
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    unknowns, variants, counts = _probe_variants(spec)
    kind = SHAPES[shape_name]["kind"]

    rows = []
    probe_meta = {}
    for tag, vspec, coeffs in variants:
        if kind == "train":
            vals = _grad_probe(arch, shape_name, vspec, mesh, n_micro,
                               build_kw)
        else:
            built = build_step(arch, shape_name, scan_layers=False,
                               n_micro=1, spec_override=vspec, **build_kw)
            art = lower_and_compile(built, mesh)
            cost = art["compiled"].cost_analysis()
            coll = collective_bytes(art["compiled"].as_text())
            vals = {"flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                    "coll_bytes": float(coll["total_bytes"])}
        rows.append((coeffs, vals))
        probe_meta[tag] = dict(vals)
    solved = _solve(unknowns, rows)

    total = {m: solved["io"][m] for m in ("flops", "bytes", "coll_bytes")}
    for k, n in counts.items():
        for m in total:
            total[m] += solved[k][m] * n
    if kind == "train":
        opt = _opt_probe(arch, mesh, build_kw)
        probe_meta["optimizer"] = opt
        for m in total:
            total[m] = total[m] * n_micro + opt[m]
    return {"arch": arch, "shape": shape_name,
            "mesh": "pod2x16x16" if multi_pod else "pod16x16",
            "unknowns": solved, "counts": counts, "probes": probe_meta,
            "composed": total, "n_micro": n_micro, "options": build_kw}


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def model_flops(arch: str, shape_name: str, n_chips: int = 256) -> float:
    """Analytic MODEL_FLOPS per chip: 6·N_active·D (train) / 2·N_active·D
    (forward-only), embeddings excluded, untied head included via N."""
    from repro.configs import get_spec
    from repro.launch.specs import SHAPES
    spec = get_spec(arch)
    info = SHAPES[shape_name]
    n_eff = spec.active_params()
    if not spec.tie_embeddings:
        n_eff -= spec.embedding_params()     # keep head, drop input gather
    tokens = {"train": info["batch"] * info["seq"],
              "prefill": info["batch"] * info["seq"],
              "decode": info["batch"]}[info["kind"]]
    mult = 6 if info["kind"] == "train" else 2
    return mult * n_eff * tokens / n_chips


def roofline_terms(composed: Dict[str, float], arch: str, shape_name: str,
                   n_chips: int = 256) -> Dict[str, Any]:
    c = composed["flops"] / PEAK_FLOPS
    m = composed["bytes"] / HBM_BW
    k = composed["coll_bytes"] / ICI_BW
    dom = max(("compute", c), ("memory", m), ("collective", k),
              key=lambda t: t[1])[0]
    mf = model_flops(arch, shape_name, n_chips)
    return {"compute_s": c, "memory_s": m, "collective_s": k,
            "dominant": dom, "model_flops_per_chip": mf,
            "model_to_hlo_flops": (mf / composed["flops"]
                                   if composed["flops"] else None),
            "bound_s": max(c, m, k)}


def run_all(shapes=None, archs=None, force: bool = False,
            tag_suffix: str = "", **kw) -> List[Dict[str, Any]]:
    from repro.configs import ASSIGNED
    from repro.launch.specs import SHAPES, shape_skip_reason
    from repro.configs import get_spec
    os.makedirs(ROOF_DIR, exist_ok=True)
    out = []
    bk_tag = "__pallas" if kw.get("backend") == "pallas" else ""
    for arch in (archs or ASSIGNED):
        for shape in (shapes or list(SHAPES)):
            tag = f"{arch}__{shape}__pod16x16{bk_tag}{tag_suffix}"
            path = os.path.join(ROOF_DIR, tag + ".json")
            if os.path.exists(path) and not force:
                with open(path) as f:
                    out.append(json.load(f))
                continue
            if shape_skip_reason(get_spec(arch), shape):
                rec = {"arch": arch, "shape": shape, "status": "skipped"}
            else:
                try:
                    pc = probe_costs(arch, shape, **kw)
                    n_chips = 512 if kw.get("multi_pod") else 256
                    rec = dict(pc, status="ok",
                               roofline=roofline_terms(pc["composed"],
                                                       arch, shape,
                                                       n_chips=n_chips))
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[roofline {tag}] {rec['status']} "
                  + (rec.get("error", "") if rec["status"] == "error" else
                     str({kk: f'{vv:.3g}' for kk, vv in
                          rec.get('roofline', {}).items()
                          if isinstance(vv, float)})))
            out.append(rec)
    return out


def render_table(records: List[Dict[str, Any]]) -> str:
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | MODEL/HLO flops |",
             "|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | - | - | - "
                         f"| {r.get('status')} | - |")
            continue
        t = r["roofline"]
        ratio = t.get("model_to_hlo_flops")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | {ratio:.2f} |" if ratio else
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant']} | - |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero", default="os+g")
    ap.add_argument("--recompute", default="none")
    ap.add_argument("--attn", default="naive")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="kernel backend for the cost probes (pallas: "
                         "interpret-mode lowering off-TPU — the probed op "
                         "mix matches what the executor's fast path runs)")
    ap.add_argument("--moe-impl", default="scatter")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--mesh-shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split("x")) \
        if args.mesh_shape else None
    recs = run_all(shapes=[args.shape] if args.shape else None,
                   archs=[args.arch] if args.arch else None,
                   force=args.force, tag_suffix=args.tag_suffix,
                   zero=args.zero, recompute=args.recompute,
                   attn_impl=args.attn, moe_impl=args.moe_impl,
                   backend=args.backend,
                   n_micro=args.n_micro,
                   capacity_factor=args.capacity_factor,
                   mesh_shape=mesh_shape, multi_pod=args.multi_pod)
    print(render_table(recs))


if __name__ == "__main__":
    import os as _os
    _os.environ.setdefault("XLA_FLAGS",
                           "--xla_force_host_platform_device_count=512")
    main()
