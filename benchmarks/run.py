"""Benchmark harness entry point: one section per paper table + kernels +
dry-run/roofline artifact summaries.  Prints ``name,us_per_call,derived``
CSV (one row per benchmark)."""

from __future__ import annotations

import json
import os
import sys


def _artifact_rows():
    rows = []
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    dry = os.path.join(art, "dryrun")
    if os.path.isdir(dry):
        n_ok = n_skip = n_err = 0
        temp_max = 0
        for f in os.listdir(dry):
            if not f.endswith(".json"):
                continue
            with open(os.path.join(dry, f)) as fh:
                d = json.load(fh)
            s = d.get("status")
            n_ok += s == "ok"
            n_skip += s == "skipped"
            n_err += s == "error"
            if s == "ok":
                temp_max = max(temp_max,
                               d.get("memory", {}).get("temp_size_in_bytes", 0))
        rows.append(("dryrun.combos_ok", 0.0, f"{n_ok}"))
        rows.append(("dryrun.combos_skipped", 0.0, f"{n_skip}"))
        rows.append(("dryrun.combos_error", 0.0, f"{n_err}"))
        rows.append(("dryrun.max_temp_gib", 0.0, f"{temp_max / 2**30:.1f}"))
    roof = os.path.join(art, "roofline")
    if os.path.isdir(roof):
        n = 0
        doms = {}
        for f in os.listdir(roof):
            if not f.endswith(".json"):
                continue
            with open(os.path.join(roof, f)) as fh:
                d = json.load(fh)
            if d.get("status") == "ok":
                n += 1
                dom = d["roofline"]["dominant"]
                doms[dom] = doms.get(dom, 0) + 1
        rows.append(("roofline.pairs_ok", 0.0, f"{n}"))
        for k, v in sorted(doms.items()):
            rows.append((f"roofline.dominant.{k}", 0.0, f"{v}"))
    return rows


def main() -> None:
    from benchmarks import kernel_bench, paper_tables
    print("name,us_per_call,derived")
    for group in (paper_tables.ALL, kernel_bench.ALL):
        for fn in group:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
    for name, us, derived in _artifact_rows():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
