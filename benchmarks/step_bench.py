"""Step-time benchmark: measured wall clock per pipeline schedule.

Runs ``make_pipeline_train_step`` over a (schedule, pp, tp, sp, ep, zero)
grid on the CPU fake-device mesh, times the *warm* jitted step
(median-of-k, blocked — ``repro.train.timing``), derives tokens/s and
analytic-FLOPs MFU, and records the two analytic views next to every
measurement:

* ``ideal_bubble_fraction`` — ``core.steptime.bubble_stats``, the paper
  story: what the schedule's bubble costs on hardware that skips idle
  slots (zb1p < 1f1b; dualpipe lowest).
* ``predicted_s`` — ``core.steptime.predict_step_time``'s *overlapped*
  view: what the cond-gated overlap engine should measure — per tick the
  active compute (F=1, fused B=4, zb1p's split B=3 / W=0.25
  chunk-forward units) with ring traffic overlapped against it.  On a
  host whose fake devices share cores (``host_serializes_ranks``) the
  per-tick cost is the *sum* of the ranks' active compute rather than
  the slowest rank's — the ranks' programs run back-to-back, so only
  total-work differences and tick counts are measurable here.
  The steps run with ``recompute=FULL`` (the documented chunk-recompute
  configuration) so the fused backward really pays the replay the model
  prices; zb1p's no-remat B skips that replay by stashing the fp32
  pending-dW instead of recomputing activations, and its W ticks are
  near-free flushes — the remat asymmetry the split exploits.  The
  sequence length is chosen long enough that the replay is real compute
  (at tiny shapes forward replay hides in memory latency and remat ≈
  no-remat, which would erase the asymmetry being measured).

Every row also records ``ticks_total`` (tick count × pp rank-ticks),
``ticks_active`` (rank-ticks with gated work) and the per-kind
``ticks_f``/``ticks_b``/``ticks_w`` sums from the exec tables, so the
artifact shows how much of each timeline the cond gates skip.

``--check-direction`` asserts the measured ranking matches the executor
model's ranking for pairs whose predicted times differ by >10% — the
CI-gated perf trajectory: an executor regression that inverts a schedule
ordering fails loudly, while CPU noise inside the 10% band cannot flake.
``--check-convergence`` is the overlap gate: measured zb1p must not
exceed measured 1f1b by more than the tie band in any shared cell, and
every pp>1 row must actually skip work (``ticks_active < ticks_total``).

Rows land in ``benchmarks/artifacts/BENCH_step.json`` keyed on the full
config tuple, newest-wins (same dedupe policy as ``validate_memory``'s
per-config artifacts), so the committed file is a perf trajectory that
re-runs extend rather than clobber.

Usage::

    python benchmarks/step_bench.py                  # full grid, write JSON
    python benchmarks/step_bench.py --smoke          # pp2-only CI tier
    python benchmarks/step_bench.py --check-direction  # gate on existing rows
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

N_DEVICES = 8

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def _ensure_fake_devices() -> None:
    """Fake an 8-device host.  Must run BEFORE jax first initialises (jax
    locks the device count), which is why this module never imports jax at
    top level and why the pure helpers (``check_direction``, ``merge_rows``)
    stay importable from the test suite without touching the environment."""
    if f"device_count={N_DEVICES}" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={N_DEVICES}").strip()

from repro.train.timing import merge_rows, time_callable  # noqa: E402

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_step.json")
# Full config identity: one row per distinct benchmark point, newest wins.
KEY_FIELDS = ("arch", "schedule", "pp", "dp", "tp", "sp", "ep", "zero",
              "n_chunks", "n_micro", "batch", "seq_len", "backend")

# (schedule, n_chunks, pp, dp, tp, sp, ep, zero) on 8 fake devices.  pp2
# legs are the CI smoke tier; pp4 legs complete the trajectory.  dualpipe
# shares each mesh; interleaved needs n_micro % pp == 0.  n_micro = 2·pp
# everywhere (``n_micro_for``): per-device micro_batch lands at 1, which
# keeps every schedule's chunk working set below the cache cliff (at
# mb=2 the 4-layer pp2 chunks go memory-bound and the remat replay —
# the very thing zb1p's split skips — becomes free, erasing the
# asymmetry under measurement) and is deep enough into steady state
# that the serialized overlapped model predicts zb1p strictly below
# 1f1b in every cell.  The pp4 schedule sweep runs sp=0 — dualpipe and
# interleaved execute 2× the chunk ops of 1f1b at half size, so SP's
# per-op gather/scatter collectives would bill them double fixed
# overhead and drown the schedule signal on this serializing host; the
# trailing sp=1 pair keeps the SP composition measured and gated where
# the op counts match (1f1b vs zb1p).
GRID = [
    ("1f1b",        1, 2, 2, 2, False, 1, "os"),
    ("zb1p",        1, 2, 2, 2, False, 1, "os"),
    ("dualpipe",    1, 2, 2, 2, False, 1, "os"),
    ("interleaved", 2, 2, 2, 2, False, 1, "os"),
    ("1f1b",        1, 4, 1, 2, False, 1, "os"),
    ("zb1p",        1, 4, 1, 2, False, 1, "os"),
    ("dualpipe",    1, 4, 1, 2, False, 1, "os"),
    ("interleaved", 2, 4, 1, 2, False, 1, "os"),
    ("1f1b",        1, 4, 1, 2, True,  1, "os"),
    ("zb1p",        1, 4, 1, 2, True,  1, "os"),
]

ARCH, BATCH, SEQ, N_LAYERS = "qwen2-1.5b", 8, 128, 8


def n_micro_for(pp: int) -> int:
    return 2 * pp


def host_serializes_ranks() -> bool:
    """True when this host cannot run the mesh's fake devices on distinct
    cores — XLA then executes the ranks' per-tick programs back-to-back,
    so measured wall clock tracks the SUM of per-rank active compute, not
    the max (``predict_step_time(serialize_ranks=...)``)."""
    return (os.cpu_count() or 1) < N_DEVICES


def host_cache_bytes() -> float:
    """Per-core private cache (largest data/unified level <= 2) from sysfs,
    0 when unreadable.  Feeds ``predict_step_time(cache_bytes=...)``: on a
    serializing host, zb1p's no-remat replay saving only materializes
    while the chunk vjp's saved intermediates stay L2-resident — measured
    here, 2-layer chunks (1.2 MB) fit a 2 MB L2 and keep the win, 4-layer
    chunks (2.5 MB) overflow it and tie with 1f1b."""
    best = 0.0
    base = "/sys/devices/system/cpu/cpu0/cache"
    try:
        entries = [e for e in os.listdir(base) if e.startswith("index")]
    except OSError:
        return 0.0
    for idx in entries:
        d = os.path.join(base, idx)
        try:
            with open(os.path.join(d, "level")) as f:
                level = int(f.read())
            with open(os.path.join(d, "type")) as f:
                kind = f.read().strip()
            if level > 2 or kind == "Instruction":
                continue
            with open(os.path.join(d, "size")) as f:
                size = f.read().strip()
        except (OSError, ValueError):
            continue
        mult = {"K": 2**10, "M": 2**20}.get(size[-1], 1)
        n = float(size[:-1] if size[-1] in "KM" else size) * mult
        best = max(best, n)
    return best


def _calibrate_peak_flops() -> float:
    """Achievable matmul FLOP/s on this host, measured the same way the
    steps are (warm, blocked, median-of-k).  MFU against an A100 peak is
    meaningless on CPU; against this calibration it is a real utilization
    number, and the calibration source is recorded in the row."""
    import jax
    import jax.numpy as jnp
    n = 1024
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    r = time_callable(f, x, iters=5, warmup=2)
    return 2 * n**3 / r.median_s


def _calibrate_bandwidth() -> float:
    """Achievable streaming bytes/s (read+write of a 128 MiB buffer).
    ``predict_step_time``'s comm/flush terms are priced against this so the
    predicted compute:traffic ratio matches the machine being measured —
    at the nominal accelerator constants the zb1p flush term would be
    ~1000x overpriced relative to CPU matmul throughput and the predicted
    ranking would not be the one any real run of THIS harness produces."""
    import jax
    import jax.numpy as jnp
    n = 1 << 25
    x = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    r = time_callable(f, x, iters=5, warmup=2)
    return 8 * n / r.median_s


def run_grid(grid, *, iters: int, out_path: str = ARTIFACT,
             quiet: bool = False,
             backend: str = "reference") -> List[Dict[str, Any]]:
    _ensure_fake_devices()
    import dataclasses
    import jax

    from repro.configs import get_spec
    from repro.core import (bubble_fraction, mfu, predict_step_time)
    from repro.core.parallel_config import RecomputePolicy, ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.models.transformer import ModelOptions
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig
    from repro.train.pipeline_loop import make_pipeline_train_step
    from repro.train.schedules import build_exec_tables, make_schedule

    spec = dataclasses.replace(get_spec(ARCH, smoke=True), n_layers=N_LAYERS)
    # recompute=FULL: the documented chunk-recompute configuration.  The
    # fused backward then really replays the chunk inside its vjp (the 4F
    # the overlapped model prices), while zb1p's no-remat B stashes the
    # pending-dW instead of replaying — the asymmetry that lets zb1p win
    # measured.
    # ``backend`` keys the rows: "pallas" routes the chunk bodies through
    # the kernel fast path (interpret mode off-TPU — expect slower wall
    # clock there; the row exists to pin the trajectory, not to win on CPU)
    model = build_model(spec, ModelOptions(recompute=RecomputePolicy.FULL,
                                           backend=backend))
    state0 = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, BATCH, SEQ), 0)
    peak = _calibrate_peak_flops()
    bw = _calibrate_bandwidth()
    tokens = BATCH * SEQ
    zmap = {"none": ZeROStage.NONE, "os": ZeROStage.OS,
            "os+g": ZeROStage.OS_G}

    rows: List[Dict[str, Any]] = []
    # Multiplicative calibration from each mesh cell's 1f1b row (first in
    # the grid per cell): the tiny smoke ops achieve a fixed fraction of
    # the 1024³-matmul calibrated peak, so the roofline underestimates
    # every schedule's active compute by roughly the same factor —
    # scaling each raw prediction by the cell's measured/raw 1f1b ratio
    # preserves the model's schedule *ratios* (what the direction gate
    # asserts) while making predicted_s the honest "what this harness
    # should measure" number.  (An additive per-tick overhead is the
    # wrong shape here: it bills zb1p's cheap cond-gated W flush ticks at
    # full dispatch cost and predicts the many-tick schedules slower than
    # they measure.)
    scale_by_cell: Dict[tuple, float] = {}
    for (schedule, n_chunks, pp, dp, tp, sp, ep, zero) in grid:
        n_micro = n_micro_for(pp)
        mesh = jax.make_mesh((pp, dp, tp), ("pipe", "data", "model"))
        step = jax.jit(make_pipeline_train_step(
            model, TrainConfig(n_micro=n_micro), mesh,
            schedule=schedule, n_chunks=n_chunks, zero=zmap[zero],
            sp=sp, ep=ep))
        res = time_callable(step, state0, batch, iters=iters, warmup=2)
        # per-device micro-batch: the global batch splits over dp, then
        # into n_micro microbatches
        mb = max(BATCH // (dp * n_micro), 1)
        cell = (pp, dp, tp, sp)
        kw = dict(micro_batch=mb, seq_len=SEQ, n_chunks=n_chunks, tp=tp,
                  sp=sp, flops_per_s=peak, bytes_per_s=bw,
                  serialize_ranks=host_serializes_ranks(),
                  cache_bytes=host_cache_bytes())
        raw = predict_step_time(spec, schedule, pp, n_micro, **kw)
        if schedule == "1f1b" and cell not in scale_by_cell:
            scale_by_cell[cell] = res.median_s / raw.total_s
        scale = scale_by_cell.get(cell, 1.0)
        pred = raw
        tab = build_exec_tables(make_schedule(schedule, pp, n_micro,
                                              n_chunks=n_chunks))
        ticks_f = int((tab.f_act > 0).sum())
        ticks_b = int((tab.b_act > 0).sum())
        ticks_w = 0 if tab.w_act is None else int((tab.w_act > 0).sum())
        row = {
            "arch": ARCH, "schedule": schedule, "pp": pp, "dp": dp,
            "tp": tp, "sp": sp, "ep": ep, "zero": zero, "backend": backend,
            "n_chunks": n_chunks, "n_micro": n_micro,
            "batch": BATCH, "seq_len": SEQ, "n_layers": N_LAYERS,
            "median_s": res.median_s, "mean_s": res.mean_s,
            "min_s": res.min_s, "iters": iters,
            "warmup_s": res.warmup_s,
            "tokens_per_s": tokens / res.median_s,
            "mfu": mfu(res.median_s, spec, tokens, SEQ,
                       peak_flops_per_s=peak, n_devices=N_DEVICES),
            "peak_flops_per_s": peak,
            "bytes_per_s": bw,
            "peak_source": "calibrated_cpu_matmul_1024",
            "ideal_bubble_fraction": bubble_fraction(
                schedule, pp, n_micro, n_chunks),
            "predicted_s": raw.total_s * scale,
            "predicted_raw_s": raw.total_s,
            "predicted_scale": scale,
            "predicted_ticks": pred.ticks,
            "ticks_total": pred.ticks * pp,
            "ticks_active": pred.ticks_active,
            "ticks_f": ticks_f, "ticks_b": ticks_b, "ticks_w": ticks_w,
        }
        rows.append(row)
        if not quiet:
            print(f"{schedule:<12} pp{pp} tp{tp} sp={int(sp)} M{n_micro} "
                  f"median={res.median_s:.4f}s tok/s={row['tokens_per_s']:.0f} "
                  f"mfu={row['mfu']:.4f} bubble={row['ideal_bubble_fraction']:.3f} "
                  f"pred={raw.total_s * scale:.4f}s "
                  f"active={pred.ticks_active}/{pred.ticks * pp}")
    write_rows(rows, out_path)
    return rows


def write_rows(rows: List[Dict[str, Any]], path: str = ARTIFACT) -> None:
    existing: List[Dict[str, Any]] = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    # rows predating the backend key ran the jnp reference path — pin it
    # so they dedupe against fresh reference rows instead of coexisting
    for r in existing:
        r.setdefault("backend", "reference")
    merged = merge_rows(existing, rows, KEY_FIELDS)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1)
        f.write("\n")


def check_direction(rows: List[Dict[str, Any]], *,
                    min_gap: float = 0.10) -> List[str]:
    """Measured-vs-predicted ranking check (the CI gate).

    Within every (pp, tp, sp, n_micro, n_chunks, batch, seq) cell, any pair
    of schedules whose *predicted* step times differ by more than
    ``min_gap`` (relative) must measure in the same order.  Pairs inside
    the band are ties — either measured order passes — so CPU noise cannot
    flake the gate, but a real inversion (e.g. an executor regression that
    makes dualpipe slower than its tick count says) fails loudly.
    ``n_chunks`` is part of the cell: interleaved ticks run half-size
    chunks, so its per-tick overhead is not comparable to the full-chunk
    schedules' on an overhead-dominated CPU host — the gate covers the
    1f1b/zb1p/dualpipe trio, which shares chunk granularity.  Returns the
    violation messages (empty == pass).
    """
    cells: Dict[tuple, List[Dict[str, Any]]] = {}
    for r in rows:
        # full mesh identity: without dp/ep/zero in the key, rows from
        # different meshes (or ZeRO stages) would be ranked against each
        # other even though their measured times are not comparable
        cell = tuple(r.get(k) for k in
                     ("arch", "pp", "dp", "tp", "sp", "ep", "zero",
                      "n_micro", "n_chunks", "batch", "seq_len", "backend"))
        cells.setdefault(cell, []).append(r)
    bad: List[str] = []
    for cell, rs in cells.items():
        for i in range(len(rs)):
            for j in range(i + 1, len(rs)):
                a, b = rs[i], rs[j]
                pa, pb = a["predicted_s"], b["predicted_s"]
                if pa > pb:
                    a, b, pa, pb = b, a, pb, pa
                if pb <= pa * (1 + min_gap):
                    continue          # predicted tie: either order is fine
                if a["median_s"] > b["median_s"]:
                    bad.append(
                        f"cell {cell}: predicted {a['schedule']}"
                        f" ({pa:.4f}s) < {b['schedule']} ({pb:.4f}s) by"
                        f" >{min_gap:.0%}, but measured"
                        f" {a['median_s']:.4f}s > {b['median_s']:.4f}s")
    return bad


def check_convergence(rows: List[Dict[str, Any]], *,
                      tie: float = 0.10) -> List[str]:
    """The overlap gate (CI's ``step-bench-smoke`` convergence check).

    Two assertions over the artifact rows:

    * in every cell holding both a ``1f1b`` and a ``zb1p`` measurement,
      measured zb1p must not exceed measured 1f1b by more than the ``tie``
      band — the cond-gated W ticks and the no-remat B/W split must keep
      zero-bubble at least competitive wherever the model calls it a tie,
      and strictly ahead where it predicts a win;
    * every pp>1 row must report ``ticks_active < ticks_total`` — the
      engine is actually skipping idle rank-ticks (a regression to masked
      always-on compute shows up here before it shows up as wall clock).

    Returns violation messages (empty == pass).  Rows predating the
    overlap engine (no ``ticks_active``) fail the second check loudly
    rather than passing silently.
    """
    bad: List[str] = []
    cells: Dict[tuple, Dict[str, Dict[str, Any]]] = {}
    for r in rows:
        cell = tuple(r.get(k) for k in
                     ("arch", "pp", "dp", "tp", "sp", "ep", "zero",
                      "n_micro", "batch", "seq_len", "backend"))
        cells.setdefault(cell, {})[r["schedule"]] = r
    for cell, by_sched in cells.items():
        if "1f1b" in by_sched and "zb1p" in by_sched:
            base = by_sched["1f1b"]["median_s"]
            zb = by_sched["zb1p"]["median_s"]
            if zb > base * (1 + tie):
                bad.append(
                    f"cell {cell}: measured zb1p {zb:.4f}s exceeds 1f1b "
                    f"{base:.4f}s by more than the {tie:.0%} tie band")
    for r in rows:
        if r.get("pp", 1) <= 1:
            continue
        total, active = r.get("ticks_total"), r.get("ticks_active")
        if total is None or active is None:
            bad.append(f"{r.get('schedule')} pp{r.get('pp')}: row lacks "
                       "ticks_total/ticks_active (pre-overlap artifact?)")
        elif not active < total:
            bad.append(
                f"{r.get('schedule')} pp{r.get('pp')} M{r.get('n_micro')}: "
                f"ticks_active {active} >= ticks_total {total} — the "
                "overlap engine is not skipping any idle rank-ticks")
    return bad


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="pp2-only tier (CI): 1f1b/dualpipe/zb1p/interleaved")
    ap.add_argument("--iters", type=int, default=5,
                    help="timed windows per config (median reported)")
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--check-direction", action="store_true",
                    help="assert measured ranking matches the executor-model "
                         "ranking in the artifact (no new measurements)")
    ap.add_argument("--check-convergence", action="store_true",
                    help="assert measured zb1p <= 1f1b within the tie band "
                         "and ticks_active < ticks_total on every pp>1 row "
                         "(no new measurements)")
    ap.add_argument("--min-gap", type=float, default=0.10,
                    help="relative predicted gap below which a pair is a tie")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="kernel backend the measured steps run "
                         "(rows are keyed on it; 'pallas' is interpret-mode "
                         "off-TPU — slower wall clock there by design)")
    args = ap.parse_args(argv)

    if args.check_direction or args.check_convergence:
        if not os.path.exists(args.out):
            print(f"no artifact at {args.out}; run the bench first",
                  file=sys.stderr)
            return 2
        with open(args.out) as f:
            rows = json.load(f)
        bad = []
        if args.check_direction:
            bad += check_direction(rows, min_gap=args.min_gap)
        if args.check_convergence:
            bad += check_convergence(rows, tie=args.min_gap)
        for msg in bad:
            print(f"DIRECTION VIOLATION: {msg}", file=sys.stderr)
        print(f"direction check: {len(rows)} rows, "
              f"{len(bad)} violations")
        return 1 if bad else 0

    grid = [g for g in GRID if g[2] == 2] if args.smoke else GRID
    rows = run_grid(grid, iters=args.iters, out_path=args.out,
                    backend=args.backend)
    print(f"wrote {len(rows)} rows -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
