"""Step-time benchmark: measured wall clock per pipeline schedule.

Runs ``make_pipeline_train_step`` over a (schedule, pp, tp, sp, ep, zero)
grid on the CPU fake-device mesh, times the *warm* jitted step
(median-of-k, blocked — ``repro.train.timing``), derives tokens/s and
analytic-FLOPs MFU, and records the two analytic views next to every
measurement:

* ``ideal_bubble_fraction`` — ``core.steptime.bubble_stats``, the paper
  story: what the schedule's bubble costs on hardware that skips masked
  work (zb1p < 1f1b; dualpipe lowest).
* ``predicted_s`` — ``core.steptime.predict_step_time``, the executor
  model: what THIS masked SPMD tick loop should measure (every rank burns
  a full F+vjp every tick, so measured time tracks exec tick count, and
  zb1p's extra W-drain tick makes it ~(T+1)/T of 1f1b here).

``--check-direction`` asserts the measured ranking matches the executor
model's ranking for pairs whose predicted times differ by >10% — the
CI-gated perf trajectory: an executor regression that inverts a schedule
ordering fails loudly, while CPU noise inside the 10% band cannot flake.

Rows land in ``benchmarks/artifacts/BENCH_step.json`` keyed on the full
config tuple, newest-wins (same dedupe policy as ``validate_memory``'s
per-config artifacts), so the committed file is a perf trajectory that
re-runs extend rather than clobber.

Usage::

    python benchmarks/step_bench.py                  # full grid, write JSON
    python benchmarks/step_bench.py --smoke          # pp2-only CI tier
    python benchmarks/step_bench.py --check-direction  # gate on existing rows
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

N_DEVICES = 8

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def _ensure_fake_devices() -> None:
    """Fake an 8-device host.  Must run BEFORE jax first initialises (jax
    locks the device count), which is why this module never imports jax at
    top level and why the pure helpers (``check_direction``, ``merge_rows``)
    stay importable from the test suite without touching the environment."""
    if f"device_count={N_DEVICES}" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={N_DEVICES}").strip()

from repro.train.timing import merge_rows, time_callable  # noqa: E402

ARTIFACT = os.path.join(os.path.dirname(__file__), "artifacts",
                        "BENCH_step.json")
# Full config identity: one row per distinct benchmark point, newest wins.
KEY_FIELDS = ("arch", "schedule", "pp", "dp", "tp", "sp", "ep", "zero",
              "n_chunks", "n_micro", "batch", "seq_len")

# (schedule, n_chunks, pp, dp, tp, sp, ep, zero) on 8 fake devices.  pp2
# legs are the CI smoke tier; pp4 legs complete the trajectory.  dualpipe
# shares each mesh; interleaved needs n_micro % pp == 0 (n_micro=4 ok).
GRID = [
    ("1f1b",        1, 2, 2, 2, False, 1, "os"),
    ("zb1p",        1, 2, 2, 2, False, 1, "os"),
    ("dualpipe",    1, 2, 2, 2, False, 1, "os"),
    ("interleaved", 2, 2, 2, 2, False, 1, "os"),
    ("1f1b",        1, 4, 1, 2, True,  1, "os"),
    ("zb1p",        1, 4, 1, 2, True,  1, "os"),
    ("dualpipe",    1, 4, 1, 2, True,  1, "os"),
    ("interleaved", 2, 4, 1, 2, True,  1, "os"),
]

ARCH, BATCH, SEQ, N_MICRO, N_LAYERS = "qwen2-1.5b", 8, 32, 4, 8


def _calibrate_peak_flops() -> float:
    """Achievable matmul FLOP/s on this host, measured the same way the
    steps are (warm, blocked, median-of-k).  MFU against an A100 peak is
    meaningless on CPU; against this calibration it is a real utilization
    number, and the calibration source is recorded in the row."""
    import jax
    import jax.numpy as jnp
    n = 1024
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    r = time_callable(f, x, iters=5, warmup=2)
    return 2 * n**3 / r.median_s


def _calibrate_bandwidth() -> float:
    """Achievable streaming bytes/s (read+write of a 128 MiB buffer).
    ``predict_step_time``'s comm/flush terms are priced against this so the
    predicted compute:traffic ratio matches the machine being measured —
    at the nominal accelerator constants the zb1p flush term would be
    ~1000x overpriced relative to CPU matmul throughput and the predicted
    ranking would not be the one any real run of THIS harness produces."""
    import jax
    import jax.numpy as jnp
    n = 1 << 25
    x = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    r = time_callable(f, x, iters=5, warmup=2)
    return 8 * n / r.median_s


def run_grid(grid, *, iters: int, out_path: str = ARTIFACT,
             quiet: bool = False) -> List[Dict[str, Any]]:
    _ensure_fake_devices()
    import dataclasses
    import jax

    from repro.configs import get_spec
    from repro.core import (bubble_fraction, mfu, predict_step_time)
    from repro.core.parallel_config import ZeROStage
    from repro.data.synthetic import config_for, make_batch
    from repro.models import build_model
    from repro.optim.adamw import init_train_state
    from repro.train.loop import TrainConfig
    from repro.train.pipeline_loop import make_pipeline_train_step

    spec = dataclasses.replace(get_spec(ARCH, smoke=True), n_layers=N_LAYERS)
    model = build_model(spec)
    state0 = init_train_state(model.init(jax.random.PRNGKey(0)))
    batch = make_batch(config_for(spec, BATCH, SEQ), 0)
    peak = _calibrate_peak_flops()
    bw = _calibrate_bandwidth()
    tokens = BATCH * SEQ
    zmap = {"none": ZeROStage.NONE, "os": ZeROStage.OS,
            "os+g": ZeROStage.OS_G}

    rows: List[Dict[str, Any]] = []
    # Per-tick dispatch overhead, calibrated from each mesh cell's 1f1b row
    # (first in the grid per cell).  On the tiny CPU smoke model wall-clock
    # is dominated by per-tick kernel-launch/masking overhead the roofline
    # terms cannot see; folding the calibrated overhead into every
    # prediction makes predicted_s the honest "what this harness should
    # measure" number — schedule differences then ride on the executor
    # tick counts, which is exactly what the direction gate asserts.
    ovh_by_cell: Dict[tuple, float] = {}
    for (schedule, n_chunks, pp, dp, tp, sp, ep, zero) in grid:
        mesh = jax.make_mesh((pp, dp, tp), ("pipe", "data", "model"))
        step = jax.jit(make_pipeline_train_step(
            model, TrainConfig(n_micro=N_MICRO), mesh,
            schedule=schedule, n_chunks=n_chunks, zero=zmap[zero],
            sp=sp, ep=ep))
        res = time_callable(step, state0, batch, iters=iters, warmup=2)
        # per-device micro-batch: the global batch splits over dp, then
        # into n_micro microbatches
        mb = max(BATCH // (dp * N_MICRO), 1)
        cell = (pp, dp, tp, sp)
        kw = dict(micro_batch=mb, seq_len=SEQ, n_chunks=n_chunks, tp=tp,
                  sp=sp, flops_per_s=peak, bytes_per_s=bw)
        raw = predict_step_time(spec, schedule, pp, N_MICRO, **kw)
        if schedule == "1f1b" and cell not in ovh_by_cell:
            ovh_by_cell[cell] = max(
                0.0, res.median_s / raw.ticks
                - raw.total_s / raw.ticks)
        # interleaved ticks run half-size chunks: overhead (mask/dispatch
        # work over the per-chunk buffers) scales with them
        ovh = ovh_by_cell.get(cell, 0.0) / n_chunks
        pred = predict_step_time(spec, schedule, pp, N_MICRO,
                                 tick_overhead_s=ovh, **kw)
        row = {
            "arch": ARCH, "schedule": schedule, "pp": pp, "dp": dp,
            "tp": tp, "sp": sp, "ep": ep, "zero": zero,
            "n_chunks": n_chunks, "n_micro": N_MICRO,
            "batch": BATCH, "seq_len": SEQ, "n_layers": N_LAYERS,
            "median_s": res.median_s, "mean_s": res.mean_s,
            "min_s": res.min_s, "iters": iters,
            "warmup_s": res.warmup_s,
            "tokens_per_s": tokens / res.median_s,
            "mfu": mfu(res.median_s, spec, tokens, SEQ,
                       peak_flops_per_s=peak, n_devices=N_DEVICES),
            "peak_flops_per_s": peak,
            "bytes_per_s": bw,
            "peak_source": "calibrated_cpu_matmul_1024",
            "ideal_bubble_fraction": bubble_fraction(
                schedule, pp, N_MICRO, n_chunks),
            "predicted_s": pred.total_s,
            "predicted_raw_s": raw.total_s,
            "predicted_ticks": pred.ticks,
            "tick_overhead_s": ovh,
        }
        rows.append(row)
        if not quiet:
            print(f"{schedule:<12} pp{pp} tp{tp} sp={int(sp)} "
                  f"median={res.median_s:.4f}s tok/s={row['tokens_per_s']:.0f} "
                  f"mfu={row['mfu']:.4f} bubble={row['ideal_bubble_fraction']:.3f} "
                  f"pred={pred.total_s:.4f}s")
    write_rows(rows, out_path)
    return rows


def write_rows(rows: List[Dict[str, Any]], path: str = ARTIFACT) -> None:
    existing: List[Dict[str, Any]] = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    merged = merge_rows(existing, rows, KEY_FIELDS)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1)
        f.write("\n")


def check_direction(rows: List[Dict[str, Any]], *,
                    min_gap: float = 0.10) -> List[str]:
    """Measured-vs-predicted ranking check (the CI gate).

    Within every (pp, tp, sp, n_micro, n_chunks, batch, seq) cell, any pair
    of schedules whose *predicted* step times differ by more than
    ``min_gap`` (relative) must measure in the same order.  Pairs inside
    the band are ties — either measured order passes — so CPU noise cannot
    flake the gate, but a real inversion (e.g. an executor regression that
    makes dualpipe slower than its tick count says) fails loudly.
    ``n_chunks`` is part of the cell: interleaved ticks run half-size
    chunks, so its per-tick overhead is not comparable to the full-chunk
    schedules' on an overhead-dominated CPU host — the gate covers the
    1f1b/zb1p/dualpipe trio, which shares chunk granularity.  Returns the
    violation messages (empty == pass).
    """
    cells: Dict[tuple, List[Dict[str, Any]]] = {}
    for r in rows:
        cell = tuple(r.get(k) for k in
                     ("arch", "pp", "tp", "sp", "n_micro", "n_chunks",
                      "batch", "seq_len"))
        cells.setdefault(cell, []).append(r)
    bad: List[str] = []
    for cell, rs in cells.items():
        for i in range(len(rs)):
            for j in range(i + 1, len(rs)):
                a, b = rs[i], rs[j]
                pa, pb = a["predicted_s"], b["predicted_s"]
                if pa > pb:
                    a, b, pa, pb = b, a, pb, pa
                if pb <= pa * (1 + min_gap):
                    continue          # predicted tie: either order is fine
                if a["median_s"] > b["median_s"]:
                    bad.append(
                        f"cell {cell}: predicted {a['schedule']}"
                        f" ({pa:.4f}s) < {b['schedule']} ({pb:.4f}s) by"
                        f" >{min_gap:.0%}, but measured"
                        f" {a['median_s']:.4f}s > {b['median_s']:.4f}s")
    return bad


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="pp2-only tier (CI): 1f1b/dualpipe/zb1p/interleaved")
    ap.add_argument("--iters", type=int, default=5,
                    help="timed windows per config (median reported)")
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--check-direction", action="store_true",
                    help="assert measured ranking matches the executor-model "
                         "ranking in the artifact (no new measurements)")
    ap.add_argument("--min-gap", type=float, default=0.10,
                    help="relative predicted gap below which a pair is a tie")
    args = ap.parse_args(argv)

    if args.check_direction:
        if not os.path.exists(args.out):
            print(f"no artifact at {args.out}; run the bench first",
                  file=sys.stderr)
            return 2
        with open(args.out) as f:
            rows = json.load(f)
        bad = check_direction(rows, min_gap=args.min_gap)
        for msg in bad:
            print(f"DIRECTION VIOLATION: {msg}", file=sys.stderr)
        print(f"direction check: {len(rows)} rows, "
              f"{len(bad)} violations")
        return 1 if bad else 0

    grid = [g for g in GRID if g[2] == 2] if args.smoke else GRID
    rows = run_grid(grid, iters=args.iters, out_path=args.out)
    print(f"wrote {len(rows)} rows -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
