"""Serving example: batched decode with the family-appropriate cache.

Shows the MLA latent-cache advantage the paper's Table 2 geometry implies:
per token, MLA caches d_c + d_hr = 576 values vs 2·n_kv·d_h = 32768 for
equivalent MHA — a 57× KV-memory reduction, computed here with
repro.core.kv_cache_bytes and then exercised with real batched decoding.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_spec
from repro.core import ParallelConfig, human_bytes, kv_cache_bytes
from repro.models import build_model
from repro.serving import ServeConfig, serve_requests

# --- analytical: KV-cache per device at decode_32k, paper's model vs MHA ---
ds = get_spec("deepseek-v3")
cfg = ParallelConfig(dp=1, tp=1, pp=1, micro_batch=128, seq_len=32768)
mla_bytes = kv_cache_bytes(ds, cfg)
import dataclasses
from repro.core.notation import AttentionKind
mha = dataclasses.replace(ds, attention=AttentionKind.MHA, mla=None)
mha_bytes = kv_cache_bytes(mha, cfg)
print("KV cache @ b=128, s=32768, 61 layers:")
print(f"  MLA latent cache : {human_bytes(mla_bytes)}")
print(f"  MHA full KV      : {human_bytes(mha_bytes)}")
print(f"  reduction        : {mha_bytes / mla_bytes:.1f}x")
print()

# --- runtime: batched requests through three cache families ---
for arch in ("deepseek-v3", "qwen2-1.5b", "rwkv6-1.6b"):
    spec = get_spec(arch, smoke=True)
    model = build_model(spec)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, spec.vocab)
    out = serve_requests(model, params, prompts,
                         ServeConfig(max_new_tokens=16, temperature=0.0),
                         cache_len=64)
    kind = ("MLA latent" if spec.attention == AttentionKind.MLA else
            ("SSM state (O(1) in context)" if spec.attn_free else "GQA KV"))
    print(f"{arch:<14} cache={kind:<28} generated shape={tuple(out.shape)} "
          f"first row={out[0, :8].tolist()}")
