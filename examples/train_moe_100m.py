"""End-to-end driver: train a ~100M-parameter DeepSeek-style MLA+MoE model
for a few hundred steps on synthetic data, with checkpointing and the
paper's Table-7 mixed-precision state.

This is the paper's model family at laptop scale: MLA attention
(compressed KV), 8 routed experts top-2 + 1 shared, first layer dense —
the same code paths the 512-chip dry-run lowers.

Run:  PYTHONPATH=src python examples/train_moe_100m.py [--steps 300]
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.core.notation import (AttentionKind, FamilyKind, MLASpec, MlpKind,
                                 MoESpec, ModelSpec)
from repro.data.synthetic import SyntheticConfig, batches
from repro.models import build_model
from repro.models.transformer import ModelOptions
from repro.optim.adamw import AdamWConfig, init_train_state
from repro.train.loop import TrainConfig, train

# ~100M params: emb 8192*512*2 + 8L*(MLA ~1.3M + MoE 9*3*512*256)
SPEC = ModelSpec(
    name="deepseek-mini-100m",
    family=FamilyKind.MOE,
    n_layers=8,
    h=512,
    n_h=8,
    n_kv=8,
    d_head=64,
    h_ff=2048,
    vocab=32768,
    attention=AttentionKind.MLA,
    mlp=MlpKind.SWIGLU,
    mla=MLASpec(d_cq=192, d_c=128, d_h=64, d_hr=32, d_v=64),
    moe=MoESpec(n_routed=8, n_active=2, n_shared=1, d_ff_expert=512,
                first_k_dense=1),
    max_seq_len=512,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_100m")
    ap.add_argument("--router", default="sigmoid",
                    choices=["softmax", "sigmoid"])
    args = ap.parse_args()

    model = build_model(SPEC, ModelOptions(router_impl=args.router,
                                           attn_impl="chunked"))
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {SPEC.name}  params={n_params/1e6:.1f}M "
          f"(analytical {SPEC.total_params()/1e6:.1f}M)")

    state = init_train_state(params)
    step0 = latest_step(args.ckpt_dir)
    if step0 is not None:
        print(f"resuming from checkpoint step {step0}")
        state = restore(args.ckpt_dir, step0, state)

    data = batches(SyntheticConfig(batch=args.batch, seq_len=args.seq,
                                   vocab=SPEC.vocab), n_steps=args.steps)
    t0 = time.perf_counter()
    state, hist = train(model, data, n_steps=args.steps,
                        cfg=TrainConfig(n_micro=2,
                                        adamw=AdamWConfig(lr=1e-3)),
                        state=state, log_every=20,
                        callback=lambda i, m: print(
                            f"  step {i:>4}  loss {m['loss']:.4f}  "
                            f"gnorm {m['grad_norm']:.2f}  "
                            f"{m['elapsed_s']:.0f}s"))
    dt = time.perf_counter() - t0
    print(f"trained {args.steps} steps in {dt:.0f}s "
          f"({args.steps / dt:.2f} steps/s)")
    path = save(args.ckpt_dir, args.steps, state)
    print(f"checkpoint -> {path}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARN: did not decrease'})")


if __name__ == "__main__":
    main()
