"""Quickstart: the paper's memory model in 60 seconds.

Reproduces the paper's Tables 3/4/6/8/10 for DeepSeek-v3 under the official
PP16@TP2@EP8 case study, then asks the beyond-paper planner a practical
question: what is the cheapest coherent configuration that fits a 64 GiB
device, and what does ZeRO buy?

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

from repro.configs import get_spec
from repro.core import (PAPER_CONFIG, ParallelConfig, RecomputePolicy,
                        ZeROStage, estimate_memory, human_bytes, plan)
from repro.core.report import (render_full_estimate, render_table3,
                               render_table4, render_table6, render_table8,
                               render_table10)

spec = get_spec("deepseek-v3")

print("=" * 72)
print("Table 3 — layer-level parameter counting")
print(render_table3(spec))
print()
print("Table 4 — PP16 stage memory")
print(render_table4(spec, pp=16))
print()
print("Table 6 — per-device static params @", PAPER_CONFIG.describe())
print(render_table6(spec, PAPER_CONFIG))
print()
print("Table 8 — ZeRO strategies")
print(render_table8(spec, PAPER_CONFIG))
print()
print("Table 10 — activation memory per 4-layer stage")
print(render_table10(spec, PAPER_CONFIG))
print()
print("Full per-device estimate across ZeRO × recompute:")
print(render_full_estimate(spec, PAPER_CONFIG))
print()

print("=" * 72)
print("Beyond the paper: planner — cheapest config fitting 64 GiB/device")
entries = plan(spec, world_size=1024, hbm_bytes=64 * 2**30, seq_len=4096,
               top_k=5)
for e in entries:
    print(f"  {e.cfg.describe():<75} total={human_bytes(e.estimate.total)}")
if not entries:
    print("  (nothing fits at 64 GiB — try ZeRO os+g+params + AC full)")

print()
print("What does each ZeRO stage buy at the paper's config?")
for z in ZeROStage:
    c = dataclasses.replace(PAPER_CONFIG, zero=z,
                            recompute=RecomputePolicy.FULL)
    e = estimate_memory(spec, c)
    print(f"  zero={z.value:<12} -> {human_bytes(e.total)} / device")
