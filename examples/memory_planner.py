"""Beyond-paper example: the memory model as a fleet-planning tool.

For every assigned architecture, find the smallest world size and the
cheapest (ZeRO, recompute, micro-batch) policy that trains seq=4096 within
a 16 GiB/chip budget (v5e-class), and show what the paper's knobs buy.

Run:  PYTHONPATH=src python examples/memory_planner.py
"""

import dataclasses

from repro.configs import ASSIGNED, get_spec
from repro.core import (ParallelConfig, RecomputePolicy, ZeROStage,
                        estimate_memory, human_bytes, plan)

HBM = 16 * 2**30     # v5e chip

print(f"{'arch':<22}{'world':>6}  best feasible config")
print("-" * 100)
for arch in ASSIGNED:
    spec = get_spec(arch)
    found = None
    for world in (8, 16, 32, 64, 128, 256, 512, 1024, 2048):
        entries = plan(spec, world, HBM, seq_len=4096, top_k=1,
                       micro_batches=(1, 2, 4))
        if entries:
            found = (world, entries[0])
            break
    if found:
        w, e = found
        run = "runnable" if e.runnable else f"dry-run ({e.why_not_runnable})"
        print(f"{arch:<22}{w:>6}  {e.cfg.describe():<72} "
              f"{human_bytes(e.estimate.total)}  [{run}]")
    else:
        print(f"{arch:<22}{'—':>6}  does not fit <=2048 chips at 16 GiB "
              f"(needs more aggressive sharding)")

print()
print("Knob-by-knob walk for qwen3-moe-235b-a22b at world=512:")
spec = get_spec("qwen3-moe-235b-a22b")
base = ParallelConfig(dp=32, tp=4, pp=4, ep=16, etp=1, sp=True,
                      micro_batch=1, seq_len=4096)
steps = [
    ("baseline (no ZeRO, AC none)", base),
    ("+ ZeRO os", dataclasses.replace(base, zero=ZeROStage.OS)),
    ("+ ZeRO os+g", dataclasses.replace(base, zero=ZeROStage.OS_G)),
    ("+ ZeRO os+g+params",
     dataclasses.replace(base, zero=ZeROStage.OS_G_PARAMS)),
    ("+ AC selective", dataclasses.replace(
        base, zero=ZeROStage.OS_G_PARAMS,
        recompute=RecomputePolicy.SELECTIVE)),
    ("+ AC full", dataclasses.replace(
        base, zero=ZeROStage.OS_G_PARAMS, recompute=RecomputePolicy.FULL)),
]
for name, cfg in steps:
    e = estimate_memory(spec, cfg)
    fits = "fits 16GiB" if e.total <= HBM else "OVER"
    print(f"  {name:<28} {human_bytes(e.total):>12}  ({fits})")
